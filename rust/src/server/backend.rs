//! Pluggable inference backends for the serving router.
//!
//! The batcher coalesces fill-mask requests into token batches; *how* a
//! batch turns into log-probabilities is behind [`InferenceBackend`]:
//!
//! * [`ArtifactBackend`] — the original path: an AOT-compiled PJRT
//!   artifact (`infer_logits_<variant>`) executed through [`crate::runtime`].
//!   Requires compiled artifacts on disk and a working PJRT runtime.
//! * [`EngineBackend`] — pure rust, no artifacts anywhere: the shared
//!   [`LramMlm`] model (dense prefix → fused [`BatchLookupEngine`]
//!   lookup→gather over a lazily-mapped [`ValueTable`] → dense suffix).
//!   It serves either deterministic seed weights
//!   ([`EngineBackend::new`], explicit opt-in on the CLI via
//!   `--random-init`) or *trained* weights restored from a checkpoint
//!   directory ([`EngineBackend::from_checkpoint`]) — the paper's
//!   O(1)-lookup serving claim, end to end, with the weights you
//!   actually trained.
//!
//! Backends are constructed *on the executor thread* via [`BackendInit`]
//! (the xla crate's handles are not `Send`), which is why the enum —
//! not the built backend — crosses the thread boundary.
//!
//! [`BatchLookupEngine`]: crate::lattice::BatchLookupEngine
//! [`ValueTable`]: crate::memstore::ValueTable

use anyhow::{bail, ensure, Result};

use crate::checkpoint::Checkpoint;
use crate::memstore::{AccessStats, QuantizedValueTable};
use crate::model::{tensor_names, LramMlm};
pub use crate::model::{EngineConfig, NumericPath};
use crate::util::sigbus;
use crate::runtime::{Artifact, ArtifactState, HostTensor, Runtime};
use crate::tokenizer::Bpe;

/// Memory-access observability for one value-table shard, as served on
/// `/stats`.  Under unsharded serving there is exactly one entry
/// covering the whole table.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStats {
    /// Shard index (`0..n_shards`).
    pub shard: usize,
    /// Rows this shard owns.
    pub rows: u64,
    /// Total accesses that landed in this shard's row range.
    pub hits: u64,
    /// Fraction of this shard's rows accessed at least once.
    pub utilization: f64,
}

/// Typed memory-access observability for backends that own a value
/// table (the Table-5 serving metrics, plus the per-shard breakdown
/// sharded serving needs to spot ownership imbalance).
#[derive(Debug, Clone, PartialEq)]
pub struct BackendStats {
    /// Fraction of all memory locations accessed at least once.
    pub utilization: f64,
    /// KL(access || uniform) in nats over the weighted distribution.
    pub kl_from_uniform: f64,
    /// One entry per shard; a single whole-table entry when unsharded.
    pub per_shard: Vec<ShardStats>,
}

/// A serving inference engine: token batches in, log-probabilities out.
///
/// `infer` takes `rows * seq_len()` token ids for `1 <= rows <=
/// max_batch()` — ragged final batches are first-class, callers never
/// pad — and returns `rows * seq_len() * vocab()` log-probs, row-major.
pub trait InferenceBackend {
    /// Human-readable backend name (surfaced in logs and `/stats`).
    fn name(&self) -> &'static str;
    /// Largest number of requests a single batch may carry.
    fn max_batch(&self) -> usize;
    /// Fixed sequence length of a batch row.
    fn seq_len(&self) -> usize;
    /// Vocabulary size of the returned log-prob rows.
    fn vocab(&self) -> usize;
    /// Run one (possibly ragged) batch.
    fn infer(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;
    /// Memory-access observability, for backends that own a value table
    /// (Table-5 in serving) — `None` for backends that don't.
    fn memory_stats(&self) -> Option<BackendStats> {
        None
    }
    /// Id of the checkpoint the backend serves, if it was restored from
    /// one (surfaced in `/stats` so operators can tell *which* trained
    /// weights are live).
    fn checkpoint_id(&self) -> Option<&str> {
        None
    }
    /// True once the backend's memory is known-corrupt and every further
    /// answer would be a lie — e.g. a contained SIGBUS on a mapped value
    /// table ([`crate::util::sigbus`]).  The executor checks this after
    /// each failed batch and, when set, stops taking work so supervision
    /// can rebuild the backend from the last good checkpoint.
    fn poisoned(&self) -> bool {
        false
    }
}

/// Everything needed to construct an [`ArtifactBackend`] on the executor
/// thread.
#[derive(Debug, Clone)]
pub struct ArtifactInit {
    pub artifact_dir: String,
    pub artifact_name: String,
    pub checkpoint: Option<Vec<u8>>,
}

/// Everything needed to restore an [`EngineBackend`] from a checkpoint
/// directory on the executor thread.
#[derive(Debug, Clone)]
pub struct CheckpointInit {
    /// Checkpoint directory (contains `manifest.json`).
    pub dir: String,
    /// Engine worker threads; 0 = all available parallelism.
    pub threads: usize,
    /// Track per-slot access statistics (Table-5 serving observability).
    pub track_stats: bool,
    /// Numeric path of the memory stage (defaults to the bit-exact f64
    /// reference; `lram serve` defaults the CLI flag to `f32`).
    pub numeric_path: NumericPath,
    /// Value-table shard workers (`lram serve --shards N`).  1 = fused
    /// single-owner path; N > 1 partitions the table row-wise across N
    /// in-process workers behind [`crate::model::ShardedMemory`].
    pub shards: usize,
}

impl CheckpointInit {
    pub fn new(dir: impl Into<String>) -> Self {
        CheckpointInit {
            dir: dir.into(),
            threads: 1,
            track_stats: true,
            numeric_path: NumericPath::F64,
            shards: 1,
        }
    }
}

/// Classify a `--checkpoint` CLI value (shared by `lram serve` and the
/// serving example): a directory containing a manifest is an engine
/// checkpoint; a plain file is a legacy artifact-state blob for the
/// PJRT path.  Returns `(engine, artifact_bytes)` — exactly one is
/// `Some`.
pub fn resolve_checkpoint_flag(
    path: &str,
    threads: usize,
) -> Result<(Option<CheckpointInit>, Option<Vec<u8>>)> {
    use anyhow::Context as _;
    let p = std::path::Path::new(path);
    if p.join(crate::checkpoint::MANIFEST_FILE).is_file() {
        log::info!("serving engine checkpoint {path}");
        Ok((Some(CheckpointInit { threads, ..CheckpointInit::new(path) }), None))
    } else {
        log::info!("restoring legacy artifact checkpoint {path}");
        let bytes = std::fs::read(p)
            .with_context(|| format!("reading artifact checkpoint {path}"))?;
        Ok((None, Some(bytes)))
    }
}

/// Which backend the executor thread should build.
#[derive(Debug, Clone)]
pub enum BackendInit {
    /// AOT PJRT artifact executor (requires artifacts + PJRT runtime).
    Artifact(ArtifactInit),
    /// Pure-rust engine-backed model with deterministic *seed* weights
    /// (untrained; tests, benches and explicit `--random-init` serving).
    Engine(EngineConfig),
    /// Pure-rust engine-backed model restored from a trained checkpoint.
    EngineCheckpoint(CheckpointInit),
}

impl BackendInit {
    /// Build the backend.  The tokenizer is the serving pipeline's: the
    /// engine backends size their embedding/output projections by its
    /// vocabulary, and a checkpoint restore validates its fingerprint
    /// against the hash recorded at training time; the artifact backend
    /// reads its own vocabulary from the manifest.
    pub fn build(&self, bpe: &Bpe) -> Result<Box<dyn InferenceBackend>> {
        match self {
            BackendInit::Artifact(init) => Ok(Box::new(ArtifactBackend::new(init)?)),
            BackendInit::Engine(cfg) => {
                Ok(Box::new(EngineBackend::new(cfg.clone(), bpe.vocab_size())?))
            }
            BackendInit::EngineCheckpoint(init) => {
                Ok(Box::new(EngineBackend::from_checkpoint(init, bpe)?))
            }
        }
    }
}

/// The original serving executor: one AOT artifact, fixed batch shape.
pub struct ArtifactBackend {
    // the PJRT client must outlive the artifact's executable handles
    _rt: Runtime,
    artifact: std::sync::Arc<Artifact>,
    state: ArtifactState,
    b_max: usize,
    seq_len: usize,
    vocab: usize,
}

impl ArtifactBackend {
    pub fn new(init: &ArtifactInit) -> Result<Self> {
        let rt = Runtime::new(&init.artifact_dir)?;
        let artifact = rt.load(&init.artifact_name)?;
        let state = match &init.checkpoint {
            Some(bytes) => ArtifactState::from_bytes(&artifact.manifest, bytes)?,
            None => artifact.initial_state()?,
        };
        let b_max = artifact.manifest.batch.b;
        let seq_len = artifact.manifest.inputs[0].shape[1];
        let vocab = artifact.manifest.outputs[artifact.manifest.n_state_outputs].shape[2];
        Ok(ArtifactBackend { _rt: rt, artifact, state, b_max, seq_len, vocab })
    }
}

impl InferenceBackend for ArtifactBackend {
    fn name(&self) -> &'static str {
        "artifact"
    }

    fn max_batch(&self) -> usize {
        self.b_max
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn infer(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let rows = tokens.len() / self.seq_len;
        ensure!(
            rows >= 1 && rows <= self.b_max && tokens.len() == rows * self.seq_len,
            "batch of {} tokens does not fit {} x {}",
            tokens.len(),
            self.b_max,
            self.seq_len
        );
        // the artifact's shape is fixed: pad ragged batches with empty rows
        let mut padded = tokens.to_vec();
        padded.resize(self.b_max * self.seq_len, 0);
        let inputs = vec![HostTensor::I32(padded, vec![self.b_max, self.seq_len])];
        let outs = self.artifact.call(&mut self.state, &inputs)?;
        let logp = outs[0].as_f32()?;
        Ok(logp[..rows * self.seq_len * self.vocab].to_vec())
    }
}

/// Artifact-free MLM serving over the shared [`LramMlm`] model: either
/// deterministic seed weights or a trained checkpoint.
pub struct EngineBackend {
    model: LramMlm,
    stats: Option<AccessStats>,
    checkpoint_id: Option<String>,
    /// [`sigbus::fault_epoch`] at construction: any later bump means a
    /// mapped blob faulted under this backend and its memory is poisoned.
    boot_epoch: u64,
}

impl EngineBackend {
    /// Deterministic seed-weight backend (untrained but well-formed —
    /// the serving-path contract is shape, determinism and throughput,
    /// not perplexity).
    pub fn new(cfg: EngineConfig, vocab: usize) -> Result<Self> {
        let track = cfg.track_stats;
        let model = LramMlm::seeded(cfg, vocab)?;
        let stats = track.then(|| AccessStats::new(model.table.rows()));
        Ok(EngineBackend { model, stats, checkpoint_id: None, boot_epoch: sigbus::fault_epoch() })
    }

    /// Restore trained weights from a checkpoint directory, validating
    /// it against the serving tokenizer.  Every mismatch — tokenizer
    /// fingerprint, vocabulary size, tensor shapes vs the recorded
    /// geometry — is a loud construction error: serving silently
    /// mispaired weights would be worse than not serving at all.
    pub fn from_checkpoint(init: &CheckpointInit, bpe: &Bpe) -> Result<Self> {
        // serving opens with the crash-recovery fallback chain: a corrupt
        // latest is quarantined and the newest verifying retained
        // predecessor is promoted (loudly) — last-good availability
        // beats refusing to boot.  Trainer resume stays on strict open.
        let ck = Checkpoint::open_with_fallback(std::path::Path::new(&init.dir))?;
        let manifest = &ck.manifest;
        let served = bpe.fingerprint();
        if manifest.tokenizer_hash != served {
            bail!(
                "checkpoint {} was trained with tokenizer {} but the serving pipeline \
                 built tokenizer {} — same corpus/vocab settings required (an id↔token \
                 drift would serve wrong predictions for every request)",
                manifest.checkpoint_id,
                manifest.tokenizer_hash,
                served
            );
        }
        ensure!(
            manifest.model.vocab == bpe.vocab_size(),
            "checkpoint {} has vocab {} but the serving tokenizer has {}",
            manifest.checkpoint_id,
            manifest.model.vocab,
            bpe.vocab_size()
        );
        let model = if init.shards > 1 {
            // sharded restore handles its own numeric-path wiring (the
            // shard workers map their q8 companions internally)
            LramMlm::from_checkpoint_sharded(&ck, init.threads, init.shards, init.numeric_path)?
        } else {
            let mut model = LramMlm::from_checkpoint(&ck, init.threads)?;
            if init.numeric_path == NumericPath::F32Q8
                && manifest.has_tensor(tensor_names::VALUES_Q8)
                && manifest.has_tensor(tensor_names::VALUES_Q8_SCALE)
            {
                // version-3 checkpoints ship the quantized companion: map
                // the codes zero-copy instead of re-quantizing a multi-GB
                // table
                let map = ck.map_i8(tensor_names::VALUES_Q8)?;
                let scales = ck.read_f32(tensor_names::VALUES_Q8_SCALE)?;
                let rows = model.table.rows();
                let q = QuantizedValueTable::from_parts(map, scales, rows, model.cfg.m)?;
                model.set_quantized_table(q)?;
                log::info!("mapped quantized value table zero-copy from the checkpoint");
            }
            model.set_numeric_path(init.numeric_path)?;
            model
        };
        let stats = init.track_stats.then(|| AccessStats::new(model.table.rows()));
        log::info!(
            "engine backend restored checkpoint {} (step {}, {} params, numeric path {}, \
             {} shard(s), kernel {})",
            manifest.checkpoint_id,
            manifest.step,
            model.param_count(),
            model.numeric_path().as_str(),
            model.cfg.shards,
            crate::lattice::simd::active_kernel_name()
        );
        Ok(EngineBackend {
            model,
            stats,
            checkpoint_id: Some(manifest.checkpoint_id.clone()),
            boot_epoch: sigbus::fault_epoch(),
        })
    }

    /// The lattice engine this backend drives (differential tests pit it
    /// against the scalar oracle on the same torus).
    pub fn engine(&self) -> &crate::lattice::BatchLookupEngine {
        &self.model.engine
    }

    /// Total parameters reachable through the value table.
    pub fn param_count(&self) -> u64 {
        self.model.param_count()
    }

    /// `infer`, but with the memory stage run through the scalar
    /// [`LatticeLookup`] oracle instead of the fused engine — the
    /// serving-path differential test (`rust/tests/server_integration.rs`)
    /// demands bit-identical output to [`InferenceBackend::infer`].
    ///
    /// [`LatticeLookup`]: crate::lattice::LatticeLookup
    pub fn infer_with_scalar_oracle(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.model.forward(tokens, true, self.stats.as_mut())
    }
}

impl InferenceBackend for EngineBackend {
    fn name(&self) -> &'static str {
        // surfaced in /stats: which numeric path answers requests
        match self.model.numeric_path() {
            NumericPath::F64 => "engine",
            NumericPath::F32 => "engine+f32",
            NumericPath::F32Q8 => "engine+f32q8",
        }
    }

    fn max_batch(&self) -> usize {
        self.model.cfg.max_batch
    }

    fn seq_len(&self) -> usize {
        self.model.cfg.seq_len
    }

    fn vocab(&self) -> usize {
        self.model.vocab
    }

    fn infer(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        // stands in for an mmap IO fault on the value table (SIGBUS-class
        // failures surface here once the table outgrows resident memory)
        if let Some(e) = crate::util::failpoint::inject("table.gather") {
            return Err(e.context("value-table gather failed"));
        }
        let out = self.model.forward(tokens, false, self.stats.as_mut());
        if self.poisoned() {
            // a real SIGBUS on a mapped blob was contained mid-batch: the
            // faulted page now reads zero, so whatever `forward` produced
            // is built on fabricated weights.  Refuse the answer; the
            // executor sees `poisoned()` and hands the backend to
            // supervision for a rebuild from the last good checkpoint.
            bail!(
                "value-table memory fault contained (SIGBUS epoch {} > boot epoch {}): a \
                 mapped checkpoint blob changed under the server; refusing to serve \
                 fabricated weights",
                sigbus::fault_epoch(),
                self.boot_epoch
            );
        }
        out
    }

    fn memory_stats(&self) -> Option<BackendStats> {
        let stats = self.stats.as_ref()?;
        let per_shard = match self.model.shard_plan() {
            Some(plan) => (0..plan.n_shards())
                .map(|s| {
                    let r = plan.range(s);
                    ShardStats {
                        shard: s,
                        rows: r.end - r.start,
                        hits: stats.hits_in(r.clone()),
                        utilization: stats.utilization_in(r),
                    }
                })
                .collect(),
            None => vec![ShardStats {
                shard: 0,
                rows: stats.locations(),
                hits: stats.total_accesses(),
                utilization: stats.utilization(),
            }],
        };
        Some(BackendStats {
            utilization: stats.utilization(),
            kl_from_uniform: stats.kl_from_uniform(),
            per_shard,
        })
    }

    fn checkpoint_id(&self) -> Option<&str> {
        self.checkpoint_id.as_deref()
    }

    fn poisoned(&self) -> bool {
        sigbus::fault_epoch() != self.boot_epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> EngineConfig {
        EngineConfig { max_batch: 2, seq_len: 8, width: 16, m: 8, ..EngineConfig::default() }
    }

    #[test]
    fn engine_backend_emits_normalised_log_probs() {
        let mut b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let tokens: Vec<i32> = (0..16).map(|i| (i % 60) + 2).collect();
        let logp = b.infer(&tokens).unwrap();
        assert_eq!(logp.len(), 16 * 64);
        for row in logp.chunks_exact(64) {
            let sum: f64 = row.iter().map(|&l| (l as f64).exp()).sum();
            assert!((sum - 1.0).abs() < 1e-3, "softmax sum {sum}");
            assert!(row.iter().all(|l| l.is_finite() && *l <= 0.0));
        }
    }

    #[test]
    fn engine_backend_is_deterministic() {
        let mut a = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let mut b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let tokens: Vec<i32> = (0..8).map(|i| i + 5).collect();
        assert_eq!(a.infer(&tokens).unwrap(), b.infer(&tokens).unwrap());
    }

    #[test]
    fn ragged_rows_match_full_batch_prefix() {
        // the same request must score identically whether it is served
        // alone or coalesced with batch-mates
        let mut b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let row_a: Vec<i32> = (0..8).map(|i| i + 5).collect();
        let row_b: Vec<i32> = (0..8).map(|i| i + 20).collect();
        let alone = b.infer(&row_a).unwrap();
        let both: Vec<i32> = row_a.iter().chain(&row_b).copied().collect();
        let coalesced = b.infer(&both).unwrap();
        assert_eq!(alone[..], coalesced[..8 * 64]);
    }

    #[test]
    fn oversized_batch_is_rejected() {
        let mut b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let tokens = vec![5i32; 3 * 8]; // max_batch is 2
        assert!(b.infer(&tokens).is_err());
        assert!(b.infer(&[]).is_err());
        assert!(b.infer(&[5, 5, 5]).is_err()); // not a multiple of seq_len
    }

    #[test]
    fn out_of_range_tokens_are_clamped_not_panicking() {
        let mut b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let tokens = vec![-3i32, 9999, 5, 5, 5, 5, 5, 5];
        let logp = b.infer(&tokens).unwrap();
        assert!(logp.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn seed_backend_reports_no_checkpoint() {
        let b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        assert!(b.checkpoint_id().is_none());
        assert!(!b.poisoned(), "fresh backend must not be poisoned");
    }

    #[test]
    fn sharded_engine_backend_matches_unsharded_and_reports_per_shard_stats() {
        let tokens: Vec<i32> = (0..16).map(|i| (i % 60) + 2).collect();
        let mut base = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let want = base.infer(&tokens).unwrap();
        let ustats = base.memory_stats().unwrap();
        assert_eq!(ustats.per_shard.len(), 1, "unsharded = one whole-table entry");
        assert_eq!(ustats.per_shard[0].utilization, ustats.utilization);
        let cfg = EngineConfig { shards: 4, ..tiny_cfg() };
        let mut b = EngineBackend::new(cfg, 64).unwrap();
        let got = b.infer(&tokens).unwrap();
        for (x, y) in want.iter().zip(&got) {
            assert_eq!(x.to_bits(), y.to_bits(), "sharded f64 serving must be bit-identical");
        }
        let stats = b.memory_stats().unwrap();
        assert_eq!(stats.per_shard.len(), 4);
        let total_rows: u64 = stats.per_shard.iter().map(|s| s.rows).sum();
        assert_eq!(total_rows, ustats.per_shard[0].rows, "shards must cover the table");
        let total_hits: u64 = stats.per_shard.iter().map(|s| s.hits).sum();
        assert!(total_hits > 0, "the batch must have recorded accesses somewhere");
    }

    #[test]
    fn numeric_paths_serve_close_log_probs_and_report_their_name() {
        let tokens: Vec<i32> = (0..16).map(|i| (i % 60) + 2).collect();
        let mut f64b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        assert_eq!(f64b.name(), "engine");
        let base = f64b.infer(&tokens).unwrap();
        for (path, name) in
            [(NumericPath::F32, "engine+f32"), (NumericPath::F32Q8, "engine+f32q8")]
        {
            let cfg = EngineConfig { numeric_path: path, ..tiny_cfg() };
            let mut b = EngineBackend::new(cfg, 64).unwrap();
            assert_eq!(b.name(), name);
            let got = b.infer(&tokens).unwrap();
            let worst =
                base.iter().zip(&got).map(|(a, c)| (a - c).abs()).fold(0.0f32, f32::max);
            assert!(worst < 2e-2, "{name} drifts {worst} from the f64 engine");
            // normalisation survives the fast path
            for row in got.chunks_exact(64) {
                let sum: f64 = row.iter().map(|&l| (l as f64).exp()).sum();
                assert!((sum - 1.0).abs() < 1e-3, "softmax sum {sum}");
            }
        }
    }
}
