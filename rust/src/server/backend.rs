//! Pluggable inference backends for the serving router.
//!
//! The batcher coalesces fill-mask requests into token batches; *how* a
//! batch turns into log-probabilities is behind [`InferenceBackend`]:
//!
//! * [`ArtifactBackend`] — the original path: an AOT-compiled PJRT
//!   artifact (`infer_logits_<variant>`) executed through [`crate::runtime`].
//!   Requires compiled artifacts on disk and a working PJRT runtime.
//! * [`EngineBackend`] — pure rust, no artifacts anywhere: a small dense
//!   prefix (token/position embeddings + query projection, the same
//!   `batch x width -> heads x 8` shape split-mode's prefix artifact
//!   produces), the fused [`BatchLookupEngine`] lookup→gather over a
//!   lazily-mapped [`ValueTable`], and a dense suffix (head combine +
//!   residual + tied output projection + log-softmax).  This is the
//!   paper's O(1)-lookup serving claim made end-to-end servable on any
//!   machine.
//!
//! Backends are constructed *on the executor thread* via [`BackendInit`]
//! (the xla crate's handles are not `Send`), which is why the enum —
//! not the built backend — crosses the thread boundary.

use anyhow::{ensure, Result};

use crate::lattice::e8::Vec8;
use crate::lattice::{BatchLookupEngine, BatchOutput, LatticeLookup, TorusK};
use crate::memstore::{AccessStats, ValueTable};
use crate::runtime::{Artifact, ArtifactState, HostTensor, Runtime};
use crate::util::rng::Rng;

/// A serving inference engine: token batches in, log-probabilities out.
///
/// `infer` takes `rows * seq_len()` token ids for `1 <= rows <=
/// max_batch()` — ragged final batches are first-class, callers never
/// pad — and returns `rows * seq_len() * vocab()` log-probs, row-major.
pub trait InferenceBackend {
    /// Human-readable backend name (surfaced in logs and `/stats`).
    fn name(&self) -> &'static str;
    /// Largest number of requests a single batch may carry.
    fn max_batch(&self) -> usize;
    /// Fixed sequence length of a batch row.
    fn seq_len(&self) -> usize;
    /// Vocabulary size of the returned log-prob rows.
    fn vocab(&self) -> usize;
    /// Run one (possibly ragged) batch.
    fn infer(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;
    /// Memory-access observability `(utilization, kl_from_uniform)`,
    /// for backends that own a value table (Table-5 in serving).
    fn memory_stats(&self) -> Option<(f64, f64)> {
        None
    }
}

/// Everything needed to construct an [`ArtifactBackend`] on the executor
/// thread.
#[derive(Debug, Clone)]
pub struct ArtifactInit {
    pub artifact_dir: String,
    pub artifact_name: String,
    pub checkpoint: Option<Vec<u8>>,
}

/// Which backend the executor thread should build.
#[derive(Debug, Clone)]
pub enum BackendInit {
    /// AOT PJRT artifact executor (requires artifacts + PJRT runtime).
    Artifact(ArtifactInit),
    /// Pure-rust engine-backed model (works everywhere).
    Engine(EngineConfig),
}

impl BackendInit {
    /// Build the backend.  `vocab` is the tokenizer's vocabulary size —
    /// the engine backend sizes its embedding/output projections by it;
    /// the artifact backend reads its own from the manifest.
    pub fn build(&self, vocab: usize) -> Result<Box<dyn InferenceBackend>> {
        match self {
            BackendInit::Artifact(init) => Ok(Box::new(ArtifactBackend::new(init)?)),
            BackendInit::Engine(cfg) => {
                Ok(Box::new(EngineBackend::new(cfg.clone(), vocab)?))
            }
        }
    }
}

/// The original serving executor: one AOT artifact, fixed batch shape.
pub struct ArtifactBackend {
    // the PJRT client must outlive the artifact's executable handles
    _rt: Runtime,
    artifact: std::sync::Arc<Artifact>,
    state: ArtifactState,
    b_max: usize,
    seq_len: usize,
    vocab: usize,
}

impl ArtifactBackend {
    pub fn new(init: &ArtifactInit) -> Result<Self> {
        let rt = Runtime::new(&init.artifact_dir)?;
        let artifact = rt.load(&init.artifact_name)?;
        let state = match &init.checkpoint {
            Some(bytes) => ArtifactState::from_bytes(&artifact.manifest, bytes)?,
            None => artifact.initial_state()?,
        };
        let b_max = artifact.manifest.batch.b;
        let seq_len = artifact.manifest.inputs[0].shape[1];
        let vocab = artifact.manifest.outputs[artifact.manifest.n_state_outputs].shape[2];
        Ok(ArtifactBackend { _rt: rt, artifact, state, b_max, seq_len, vocab })
    }
}

impl InferenceBackend for ArtifactBackend {
    fn name(&self) -> &'static str {
        "artifact"
    }

    fn max_batch(&self) -> usize {
        self.b_max
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn infer(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let rows = tokens.len() / self.seq_len;
        ensure!(
            rows >= 1 && rows <= self.b_max && tokens.len() == rows * self.seq_len,
            "batch of {} tokens does not fit {} x {}",
            tokens.len(),
            self.b_max,
            self.seq_len
        );
        // the artifact's shape is fixed: pad ragged batches with empty rows
        let mut padded = tokens.to_vec();
        padded.resize(self.b_max * self.seq_len, 0);
        let inputs = vec![HostTensor::I32(padded, vec![self.b_max, self.seq_len])];
        let outs = self.artifact.call(&mut self.state, &inputs)?;
        let logp = outs[0].as_f32()?;
        Ok(logp[..rows * self.seq_len * self.vocab].to_vec())
    }
}

/// Configuration of the pure-rust [`EngineBackend`].
///
/// The default shapes mirror split-mode's LRAM-small layer: `2^18` torus
/// slots, 32 hits per query, `m = 64`-dim values — small enough to build
/// in milliseconds, structured exactly like the billion-slot case (the
/// value table is lazily mapped, so only touched rows go resident).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_batch: usize,
    pub seq_len: usize,
    /// dense model width (split-mode `w`)
    pub width: usize,
    /// independent lattice query heads per position
    pub heads: usize,
    /// value-table row dimension (split-mode `m`)
    pub m: usize,
    /// hits kept per query
    pub k_top: usize,
    /// torus side lengths (each a positive multiple of 4)
    pub torus_k: [i64; 8],
    /// engine worker threads; 0 = all available parallelism
    pub threads: usize,
    /// deterministic weight-init seed
    pub seed: u64,
    /// scale applied to projected queries so they spread over the torus
    pub query_scale: f64,
    /// track per-slot access statistics (Table-5 serving observability)
    pub track_stats: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            seq_len: 32,
            width: 64,
            heads: 2,
            m: 64,
            k_top: 32,
            torus_k: [16, 16, 8, 8, 8, 8, 8, 8],
            threads: 1,
            seed: 0xE85E44E,
            query_scale: 4.0,
            track_stats: true,
        }
    }
}

/// Artifact-free MLM serving: dense prefix → fused lattice lookup+gather
/// → dense suffix, all pure rust.  Weights are deterministic from
/// `cfg.seed` (an untrained but well-formed model — the serving-path
/// contract is shape, determinism and throughput, not perplexity).
pub struct EngineBackend {
    cfg: EngineConfig,
    vocab: usize,
    /// token embeddings, `vocab x width`
    embed: Vec<f32>,
    /// position embeddings, `seq_len x width`
    pos: Vec<f32>,
    /// query projection, `(heads * 8) x width`
    wq: Vec<f32>,
    /// head-combine projection, `width x (heads * m)`
    wo: Vec<f32>,
    /// output projection, `vocab x width`
    w_out: Vec<f32>,
    engine: BatchLookupEngine,
    table: ValueTable,
    stats: Option<AccessStats>,
    // reusable scratch, allocated once at max-batch size
    h: Vec<f32>,
    queries: Vec<f64>,
    lk: BatchOutput,
    gathered: Vec<f32>,
}

impl EngineBackend {
    pub fn new(cfg: EngineConfig, vocab: usize) -> Result<Self> {
        ensure!(vocab > 0, "vocab must be positive");
        ensure!(cfg.max_batch >= 1, "max_batch must be at least 1");
        ensure!(cfg.seq_len >= 2, "seq_len must be at least 2");
        ensure!(cfg.width > 0 && cfg.heads > 0 && cfg.m > 0, "degenerate shape");
        let torus = TorusK::new(cfg.torus_k)?;
        let threads = if cfg.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.threads
        };
        let engine = BatchLookupEngine::with_threads(torus, cfg.k_top, threads);
        let locations = torus.num_locations();
        let mut table = ValueTable::zeros(locations, cfg.m)?;
        // deterministic non-zero values; initialisation capped so huge
        // tori stay lazily mapped (untouched rows read as zero)
        table.randomize_rows(cfg.seed ^ 0xE8, 0.02, locations.min(1 << 15));

        let mut rng = Rng::new(cfg.seed);
        let mut normal = |n: usize, std: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * std) as f32).collect()
        };
        let inv_sqrt_w = 1.0 / (cfg.width as f64).sqrt();
        let embed = normal(vocab * cfg.width, 1.0);
        let pos = normal(cfg.seq_len * cfg.width, 0.5);
        let wq = normal(cfg.heads * 8 * cfg.width, inv_sqrt_w);
        let wo = normal(cfg.width * cfg.heads * cfg.m, 0.05);
        let w_out = normal(vocab * cfg.width, inv_sqrt_w);

        let max_positions = cfg.max_batch * cfg.seq_len;
        Ok(EngineBackend {
            vocab,
            embed,
            pos,
            wq,
            wo,
            w_out,
            engine,
            table,
            stats: cfg.track_stats.then(|| AccessStats::new(locations)),
            h: vec![0.0; max_positions * cfg.width],
            queries: vec![0.0; max_positions * cfg.heads * 8],
            lk: BatchOutput::default(),
            gathered: vec![0.0; max_positions * cfg.heads * cfg.m],
            cfg,
        })
    }

    /// The lattice engine this backend drives (differential tests pit it
    /// against the scalar oracle on the same torus).
    pub fn engine(&self) -> &BatchLookupEngine {
        &self.engine
    }

    /// Total parameters reachable through the value table.
    pub fn param_count(&self) -> u64 {
        self.table.param_count()
    }

    /// `infer`, but with the memory stage run through the scalar
    /// [`LatticeLookup`] oracle instead of the fused engine — the
    /// serving-path differential test (`rust/tests/server_integration.rs`)
    /// demands bit-identical output to [`InferenceBackend::infer`].
    pub fn infer_with_scalar_oracle(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.forward(tokens, true)
    }

    fn clamp_token(&self, t: i32) -> usize {
        if t < 0 || t as usize >= self.vocab {
            (crate::tokenizer::UNK_ID as usize).min(self.vocab - 1)
        } else {
            t as usize
        }
    }

    fn forward(&mut self, tokens: &[i32], use_oracle: bool) -> Result<Vec<f32>> {
        let (seq_len, width, heads, m) =
            (self.cfg.seq_len, self.cfg.width, self.cfg.heads, self.cfg.m);
        let rows = tokens.len() / seq_len;
        ensure!(
            rows >= 1 && rows <= self.cfg.max_batch && tokens.len() == rows * seq_len,
            "batch of {} tokens does not fit {} x {seq_len}",
            tokens.len(),
            self.cfg.max_batch
        );
        let positions = rows * seq_len;

        // dense prefix 1/2: token + position embeddings with a cheap
        // neighbour mix so mask predictions depend on their context
        for r in 0..rows {
            for c in 0..seq_len {
                let p = r * seq_len + c;
                // resolve neighbour ids before borrowing the h row
                let t = self.clamp_token(tokens[p]);
                let left = (c > 0).then(|| self.clamp_token(tokens[p - 1]));
                let right = (c + 1 < seq_len).then(|| self.clamp_token(tokens[p + 1]));
                let e = &self.embed[t * width..(t + 1) * width];
                let pe = &self.pos[c * width..(c + 1) * width];
                let h = &mut self.h[p * width..(p + 1) * width];
                for w in 0..width {
                    h[w] = e[w] + pe[w];
                }
                if let Some(lt) = left {
                    let le = &self.embed[lt * width..(lt + 1) * width];
                    for w in 0..width {
                        h[w] += 0.5 * le[w];
                    }
                }
                if let Some(rt) = right {
                    let re = &self.embed[rt * width..(rt + 1) * width];
                    for w in 0..width {
                        h[w] += 0.5 * re[w];
                    }
                }
            }
        }

        // dense prefix 2/2: project each position to `heads` 8-d lattice
        // queries (the split-mode prefix shape), f64 for the engine
        for p in 0..positions {
            let h = &self.h[p * width..(p + 1) * width];
            for head in 0..heads {
                for d in 0..8 {
                    let wrow = &self.wq[(head * 8 + d) * width..(head * 8 + d + 1) * width];
                    let mut acc = 0.0f64;
                    for w in 0..width {
                        acc += wrow[w] as f64 * h[w] as f64;
                    }
                    self.queries[(p * heads + head) * 8 + d] = acc * self.cfg.query_scale;
                }
            }
        }

        // the O(1) memory stage: fused lookup+gather (or the scalar
        // oracle, bit-identical, for differential testing)
        let n_queries = positions * heads;
        if use_oracle {
            let k_top = self.engine.k_top;
            let mut oracle = LatticeLookup::new(self.engine.torus, k_top);
            let mut idx_row = vec![0u64; k_top];
            let mut w_row = vec![0.0f32; k_top];
            for qi in 0..n_queries {
                let q: Vec8 = self.queries[qi * 8..(qi + 1) * 8].try_into().unwrap();
                let r = oracle.lookup(&q);
                for j in 0..k_top {
                    match r.hits.get(j) {
                        Some(hit) => {
                            idx_row[j] = hit.index;
                            w_row[j] = hit.weight as f32;
                        }
                        None => {
                            idx_row[j] = 0;
                            w_row[j] = 0.0;
                        }
                    }
                }
                self.table.gather_weighted(
                    &idx_row,
                    &w_row,
                    &mut self.gathered[qi * m..(qi + 1) * m],
                );
                if let Some(stats) = self.stats.as_mut() {
                    stats.record_batch_f32(&idx_row, &w_row);
                }
            }
        } else {
            self.engine.lookup_gather_ragged_into(
                &self.queries[..n_queries * 8],
                &self.table,
                &mut self.lk,
                &mut self.gathered,
            );
            if let Some(stats) = self.stats.as_mut() {
                stats.record_batch_f32(&self.lk.indices, &self.lk.weights);
            }
        }

        // dense suffix: head combine + residual, tied output projection,
        // log-softmax per position
        let hm = heads * m;
        let mut out = vec![0.0f32; positions * self.vocab];
        let mut y = vec![0.0f32; width];
        for p in 0..positions {
            let h = &self.h[p * width..(p + 1) * width];
            let v = &self.gathered[p * hm..(p + 1) * hm];
            for (w, yw) in y.iter_mut().enumerate() {
                let wo_row = &self.wo[w * hm..(w + 1) * hm];
                let mut acc = h[w];
                for j in 0..hm {
                    acc += wo_row[j] * v[j];
                }
                *yw = acc;
            }
            let orow = &mut out[p * self.vocab..(p + 1) * self.vocab];
            let mut maxv = f32::NEG_INFINITY;
            for (t, o) in orow.iter_mut().enumerate() {
                let wrow = &self.w_out[t * width..(t + 1) * width];
                let mut acc = 0.0f32;
                for w in 0..width {
                    acc += wrow[w] * y[w];
                }
                *o = acc;
                if acc > maxv {
                    maxv = acc;
                }
            }
            let mut sum = 0.0f64;
            for &o in orow.iter() {
                sum += ((o - maxv) as f64).exp();
            }
            let lse = maxv as f64 + sum.ln();
            for o in orow.iter_mut() {
                *o = (*o as f64 - lse) as f32;
            }
        }
        Ok(out)
    }
}

impl InferenceBackend for EngineBackend {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn max_batch(&self) -> usize {
        self.cfg.max_batch
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn infer(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.forward(tokens, false)
    }

    fn memory_stats(&self) -> Option<(f64, f64)> {
        self.stats.as_ref().map(|s| (s.utilization(), s.kl_from_uniform()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> EngineConfig {
        EngineConfig { max_batch: 2, seq_len: 8, width: 16, m: 8, ..EngineConfig::default() }
    }

    #[test]
    fn engine_backend_emits_normalised_log_probs() {
        let mut b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let tokens: Vec<i32> = (0..16).map(|i| (i % 60) + 2).collect();
        let logp = b.infer(&tokens).unwrap();
        assert_eq!(logp.len(), 16 * 64);
        for row in logp.chunks_exact(64) {
            let sum: f64 = row.iter().map(|&l| (l as f64).exp()).sum();
            assert!((sum - 1.0).abs() < 1e-3, "softmax sum {sum}");
            assert!(row.iter().all(|l| l.is_finite() && *l <= 0.0));
        }
    }

    #[test]
    fn engine_backend_is_deterministic() {
        let mut a = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let mut b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let tokens: Vec<i32> = (0..8).map(|i| i + 5).collect();
        assert_eq!(a.infer(&tokens).unwrap(), b.infer(&tokens).unwrap());
    }

    #[test]
    fn ragged_rows_match_full_batch_prefix() {
        // the same request must score identically whether it is served
        // alone or coalesced with batch-mates
        let mut b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let row_a: Vec<i32> = (0..8).map(|i| i + 5).collect();
        let row_b: Vec<i32> = (0..8).map(|i| i + 20).collect();
        let alone = b.infer(&row_a).unwrap();
        let both: Vec<i32> = row_a.iter().chain(&row_b).copied().collect();
        let coalesced = b.infer(&both).unwrap();
        assert_eq!(alone[..], coalesced[..8 * 64]);
    }

    #[test]
    fn oversized_batch_is_rejected() {
        let mut b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let tokens = vec![5i32; 3 * 8]; // max_batch is 2
        assert!(b.infer(&tokens).is_err());
        assert!(b.infer(&[]).is_err());
        assert!(b.infer(&[5, 5, 5]).is_err()); // not a multiple of seq_len
    }

    #[test]
    fn out_of_range_tokens_are_clamped_not_panicking() {
        let mut b = EngineBackend::new(tiny_cfg(), 64).unwrap();
        let tokens = vec![-3i32, 9999, 5, 5, 5, 5, 5, 5];
        let logp = b.infer(&tokens).unwrap();
        assert!(logp.iter().all(|l| l.is_finite()));
    }
}
