//! Dynamic batcher: coalesce concurrent fill-mask requests into one
//! inference-backend batch (max-batch-or-timeout policy, the same shape
//! as vLLM's router loop).  The backend behind the batch is pluggable
//! ([`super::backend::InferenceBackend`]): the AOT PJRT artifact or the
//! pure-rust lattice engine.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::mlm::fit_length;
use crate::tokenizer::{Bpe, CLS_ID, MASK_ID, SEP_ID};
use crate::util::hist::Histogram;

use super::api::{MaskPrediction, PredictRequest, PredictResponse, TokenScore};
use super::backend::BackendInit;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max time a request waits for batch-mates.
    pub max_wait: Duration,
    pub top_k_cap: usize,
    /// Bounded admission: max requests admitted but not yet replied to
    /// (queued + in-flight).  Submissions beyond this are shed with
    /// [`SubmitError::Overloaded`] — the HTTP layer turns that into a
    /// `429 Too Many Requests` with `Retry-After` — instead of growing
    /// an unbounded queue whose tail latency nobody survives.
    pub max_pending: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_wait: Duration::from_millis(20), top_k_cap: 20, max_pending: 1024 }
    }
}

/// Why a submission did not produce predictions.  The split is the HTTP
/// status boundary: the front door maps `BadRequest` to 400,
/// `Overloaded` to 429 + `Retry-After`, and `Internal` to 500.
#[derive(Debug)]
pub enum SubmitError {
    /// The request itself is invalid (e.g. no `[MASK]` token).
    BadRequest(String),
    /// The bounded admission queue is full; the request was shed
    /// *before* tokenization and never reached the backend.
    Overloaded { queue_depth: usize, max_pending: usize },
    /// The batcher or backend failed; the request was admitted but
    /// could not be answered.
    Internal(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::BadRequest(m) => write!(f, "{m}"),
            SubmitError::Overloaded { queue_depth, max_pending } => write!(
                f,
                "server overloaded: {queue_depth} requests pending (admission cap {max_pending})"
            ),
            SubmitError::Internal(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Pending {
    tokens: Vec<i32>,
    mask_positions: Vec<usize>,
    top_k: usize,
    reply: Sender<Result<PredictResponse>>,
    enqueued: Instant,
}

/// The batcher: submit() from any thread; a scheduler thread drains the
/// queue into backend-sized batches.  Admission is bounded: at most
/// `max_pending` requests may be queued or in flight at once, the rest
/// are shed at the door.
pub struct Batcher {
    tx: Sender<Pending>,
    /// requests admitted but not yet replied to (queued + in-flight);
    /// incremented at admission, decremented by the executor at reply
    pending: Arc<AtomicUsize>,
    max_pending: usize,
    /// the backend's max batch rows (set once the executor builds it);
    /// sizes the adaptive `Retry-After` estimate
    batch_capacity: Arc<AtomicUsize>,
    /// rolling access statistics (Table-5 style observability in serving)
    pub stats: Arc<Mutex<BatchStats>>,
}

#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    pub requests: u64,
    pub batches: u64,
    /// sum of true request latencies (enqueue → reply) over `requests`
    pub total_request_latency_ms: f64,
    /// sum of backend execution time over `batches`
    pub total_exec_latency_ms: f64,
    pub max_batch_fill: usize,
    /// masks reported as truncated (explicit per-mask errors)
    pub truncated_masks: u64,
    /// requests shed at admission (bounded queue full, 429 to clients);
    /// shed requests never reach the backend and are not in `requests`
    pub shed: u64,
    /// request latency distribution (enqueue → reply), for p50/p95/p99
    /// in `/stats`
    pub latency: Histogram,
    /// backend name ("artifact" / "engine")
    pub backend: &'static str,
    /// id of the checkpoint the backend serves, when restored from one
    pub checkpoint: Option<String>,
    /// value-table observability from engine-owned backends (last poll)
    pub memory_utilization: Option<f64>,
    pub memory_kl: Option<f64>,
}

impl Batcher {
    /// Spawn the scheduler/executor thread.  Blocks until the backend is
    /// constructed (or construction fails).  The backend is built *on*
    /// the executor thread — PJRT handles are not `Send`, and the engine
    /// backend's scratch has no reason to cross threads either.
    pub fn spawn(init: BackendInit, bpe: Arc<Bpe>, cfg: BatcherConfig) -> Result<Arc<Batcher>> {
        let (tx, rx): (Sender<Pending>, Receiver<Pending>) = channel();
        let stats = Arc::new(Mutex::new(BatchStats::default()));
        let pending = Arc::new(AtomicUsize::new(0));
        let batch_capacity = Arc::new(AtomicUsize::new(1));
        let batcher = Arc::new(Batcher {
            tx,
            pending: pending.clone(),
            max_pending: cfg.max_pending,
            batch_capacity: batch_capacity.clone(),
            stats: stats.clone(),
        });
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        std::thread::spawn(move || {
            let mut backend = match init.build(&bpe) {
                Ok(b) => {
                    let mut s = stats.lock().unwrap();
                    s.backend = b.name();
                    s.checkpoint = b.checkpoint_id().map(str::to_string);
                    drop(s);
                    let _ = ready_tx.send(Ok(()));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let b_max = backend.max_batch();
            batch_capacity.store(b_max.max(1), Ordering::Relaxed);
            let seq_len = backend.seq_len();
            let vocab = backend.vocab();
            loop {
                // block for the first request, then collect until full or
                // the oldest request exceeds max_wait
                let first = match rx.recv() {
                    Ok(p) => p,
                    Err(_) => return, // all senders dropped: shut down
                };
                let mut group = vec![first];
                let deadline = group[0].enqueued + cfg.max_wait;
                while group.len() < b_max {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(p) => group.push(p),
                        Err(_) => break,
                    }
                }
                let t0 = Instant::now();
                let fill = group.len();
                // ragged batch: exactly the filled rows, no padding —
                // backends own their shape requirements
                let mut tokens = Vec::with_capacity(fill * seq_len);
                for p in &group {
                    tokens.extend(fit_length(p.tokens.clone(), seq_len));
                }
                let result = backend.infer(&tokens);
                let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
                {
                    let mut s = stats.lock().unwrap();
                    s.requests += fill as u64;
                    s.batches += 1;
                    s.total_exec_latency_ms += exec_ms;
                    s.max_batch_fill = s.max_batch_fill.max(fill);
                    if let Some((util, kl)) = backend.memory_stats() {
                        s.memory_utilization = Some(util);
                        s.memory_kl = Some(kl);
                    }
                }
                match result {
                    Ok(logp) => {
                        let mut latencies = Vec::with_capacity(fill);
                        let mut truncated = 0u64;
                        for (row, p) in group.into_iter().enumerate() {
                            let mut resp = extract_predictions(
                                &logp, row, seq_len, vocab, &p, &bpe, cfg.top_k_cap, fill,
                            );
                            truncated +=
                                resp.masks.iter().filter(|m| m.is_truncated()).count() as u64;
                            // true request latency: enqueue → reply, so
                            // queueing and batch collection are included
                            let latency = p.enqueued.elapsed().as_secs_f64() * 1e3;
                            resp.latency_ms = latency;
                            latencies.push(latency);
                            // release the admission slot *before* the
                            // reply wakes the client: a client that
                            // pipelines its next request immediately
                            // must never be shed against its own slot
                            pending.fetch_sub(1, Ordering::AcqRel);
                            let _ = p.reply.send(Ok(resp));
                        }
                        let mut s = stats.lock().unwrap();
                        for &l in &latencies {
                            s.total_request_latency_ms += l;
                            s.latency.record(l);
                        }
                        s.truncated_masks += truncated;
                    }
                    Err(e) => {
                        let msg = format!("inference failed: {e:#}");
                        // failed requests still count toward the latency
                        // mean (`requests` was already incremented above)
                        let mut latencies = Vec::with_capacity(fill);
                        for p in group {
                            latencies.push(p.enqueued.elapsed().as_secs_f64() * 1e3);
                            pending.fetch_sub(1, Ordering::AcqRel);
                            let _ = p.reply.send(Err(anyhow!(msg.clone())));
                        }
                        let mut s = stats.lock().unwrap();
                        for &l in &latencies {
                            s.total_request_latency_ms += l;
                            s.latency.record(l);
                        }
                    }
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during setup"))??;
        Ok(batcher)
    }

    /// Resolve a `--backend artifact | engine | auto` flag into a
    /// spawned batcher (shared by `lram serve` and the serving example).
    ///
    /// When `checkpoint` is set, the engine path serves *trained*
    /// weights from that directory.  Without one, `--backend engine`
    /// requires the explicit `random_init` opt-in (seed weights are for
    /// tests, benches and demos — serving them by accident would look
    /// exactly like a trained model with terrible predictions).  `auto`
    /// prefers checkpoint > artifact > seed engine (with a loud warning
    /// on the last fallback).
    pub fn spawn_for_flag(
        flag: &str,
        artifact: super::backend::ArtifactInit,
        engine: super::backend::EngineConfig,
        checkpoint: Option<super::backend::CheckpointInit>,
        random_init: bool,
        bpe: Arc<Bpe>,
        cfg: BatcherConfig,
    ) -> Result<Arc<Batcher>> {
        let engine_init = |random_ok: bool| -> Result<BackendInit> {
            match (&checkpoint, random_ok) {
                (Some(ck), _) => Ok(BackendInit::EngineCheckpoint(ck.clone())),
                (None, true) => Ok(BackendInit::Engine(engine.clone())),
                (None, false) => Err(anyhow!(
                    "the engine backend serves trained weights from a checkpoint; \
                     pass --checkpoint DIR, or --random-init to explicitly serve \
                     deterministic untrained seed weights"
                )),
            }
        };
        match flag {
            "artifact" => {
                // an *engine* checkpoint cannot drive the artifact
                // executor; ignoring it would serve different weights
                // than the operator just asked for
                if checkpoint.is_some() {
                    return Err(anyhow!(
                        "--checkpoint points at an engine checkpoint directory, which \
                         --backend artifact cannot serve; use --backend engine (or auto)"
                    ));
                }
                Self::spawn(BackendInit::Artifact(artifact), bpe, cfg)
            }
            "engine" => Self::spawn(engine_init(random_init)?, bpe, cfg),
            "auto" => {
                if checkpoint.is_some() {
                    return Self::spawn(engine_init(random_init)?, bpe, cfg);
                }
                match Self::spawn(BackendInit::Artifact(artifact), bpe.clone(), cfg.clone()) {
                    Ok(b) => Ok(b),
                    Err(e) => {
                        log::warn!(
                            "artifact backend unavailable ({e:#}); serving the pure-rust \
                             engine backend with UNTRAINED seed weights — train and pass \
                             --checkpoint DIR for a real model"
                        );
                        Self::spawn(BackendInit::Engine(engine.clone()), bpe, cfg)
                    }
                }
            }
            other => Err(anyhow!("unknown backend '{other}' (use artifact | engine | auto)")),
        }
    }

    /// Requests admitted but not yet replied to (queued + in-flight).
    pub fn queue_depth(&self) -> usize {
        self.pending.load(Ordering::Relaxed)
    }

    /// The bounded-admission cap this batcher sheds beyond.
    pub fn max_pending(&self) -> usize {
        self.max_pending
    }

    /// Suggested client back-off for shed responses, estimated from the
    /// live queue depth and the measured mean batch execution latency
    /// (ROADMAP PR-4 "Adaptive Retry-After": a well-behaved client
    /// should back off proportionally to actual overload, not a
    /// constant).  Clients see this as the `Retry-After` header on
    /// every 429.
    pub fn retry_after_secs(&self) -> u64 {
        let mean_batch_ms = {
            let s = self.stats.lock().unwrap();
            if s.batches > 0 { s.total_exec_latency_ms / s.batches as f64 } else { 0.0 }
        };
        estimate_retry_after(
            self.queue_depth(),
            self.batch_capacity.load(Ordering::Relaxed),
            mean_batch_ms,
        )
    }

    /// Tokenize + enqueue a request; blocks until the response is ready.
    /// Convenience wrapper over [`Self::submit_bounded`] that flattens
    /// the typed error (tests and non-HTTP callers).
    pub fn submit(&self, bpe: &Bpe, req: &PredictRequest) -> Result<PredictResponse> {
        self.submit_bounded(bpe, req).map_err(anyhow::Error::from)
    }

    /// Tokenize + enqueue a request under bounded admission; blocks
    /// until the response is ready or the request is shed.
    ///
    /// Admission is checked *first* — shedding under overload must be
    /// the cheapest path through this function, and a shed request
    /// never reaches the backend (it is not even tokenized).
    pub fn submit_bounded(
        &self,
        bpe: &Bpe,
        req: &PredictRequest,
    ) -> Result<PredictResponse, SubmitError> {
        // claim an admission slot (lock-free; contended only at the cap)
        let mut cur = self.pending.load(Ordering::Relaxed);
        loop {
            if cur >= self.max_pending {
                self.stats.lock().unwrap().shed += 1;
                return Err(SubmitError::Overloaded {
                    queue_depth: cur,
                    max_pending: self.max_pending,
                });
            }
            match self.pending.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        let release = |this: &Self| {
            this.pending.fetch_sub(1, Ordering::AcqRel);
        };
        let (tokens, mask_positions) = encode_with_masks(bpe, &req.text);
        if mask_positions.is_empty() {
            release(self);
            return Err(SubmitError::BadRequest("request contains no [MASK] token".into()));
        }
        let (reply_tx, reply_rx) = channel();
        let sent = self.tx.send(Pending {
            tokens,
            mask_positions,
            top_k: req.top_k,
            reply: reply_tx,
            enqueued: Instant::now(),
        });
        if sent.is_err() {
            release(self);
            return Err(SubmitError::Internal("batcher is shut down".into()));
        }
        // the executor owns the slot now: it decrements after replying,
        // so queue depth counts in-flight work, not just the channel
        match reply_rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(SubmitError::Internal(format!("{e:#}"))),
            Err(_) => Err(SubmitError::Internal("batcher dropped the request".into())),
        }
    }
}

/// `Retry-After` never suggests waiting longer than this, however deep
/// the queue — past a minute the client should be re-resolving, not
/// sleeping on one overloaded replica.
const MAX_RETRY_AFTER_SECS: u64 = 60;

/// The adaptive `Retry-After` estimate: the shed request would sit
/// behind `ceil(queue_depth / batch_capacity)` batches of roughly
/// `mean_batch_ms` each, so that is how long the client should wait
/// before trying again — floored at 1s (the HTTP-date-free minimum that
/// still means "back off") and capped at [`MAX_RETRY_AFTER_SECS`].
/// With no execution history yet the estimate degrades to the old
/// constant 1.
fn estimate_retry_after(queue_depth: usize, batch_capacity: usize, mean_batch_ms: f64) -> u64 {
    let batches_ahead = queue_depth.div_ceil(batch_capacity.max(1));
    let wait_secs = batches_ahead as f64 * mean_batch_ms.max(0.0) / 1e3;
    (wait_secs.ceil() as u64).clamp(1, MAX_RETRY_AFTER_SECS)
}

/// Tokenize text, mapping literal `[MASK]` spans to the mask id.
pub fn encode_with_masks(bpe: &Bpe, text: &str) -> (Vec<i32>, Vec<usize>) {
    let mut ids = vec![CLS_ID];
    let mut masks = Vec::new();
    let mut rest = text;
    while let Some(pos) = rest.find("[MASK]") {
        ids.extend(bpe.encode(&rest[..pos]));
        masks.push(ids.len());
        ids.push(MASK_ID);
        rest = &rest[pos + "[MASK]".len()..];
    }
    ids.extend(bpe.encode(rest));
    ids.push(SEP_ID);
    (ids, masks)
}

#[allow(clippy::too_many_arguments)]
fn extract_predictions(
    logp: &[f32],
    row: usize,
    seq_len: usize,
    vocab: usize,
    p: &Pending,
    bpe: &Bpe,
    top_k_cap: usize,
    batch_size: usize,
) -> PredictResponse {
    let mut masks = Vec::with_capacity(p.mask_positions.len());
    for &pos in &p.mask_positions {
        if pos >= seq_len {
            // the mask fell off the fixed-length batch row: surface an
            // explicit error, never a silent empty prediction
            masks.push(MaskPrediction::Truncated { position: pos, seq_len });
            continue;
        }
        let base = row * seq_len * vocab + pos * vocab;
        let scores = &logp[base..base + vocab];
        let k = p.top_k.min(top_k_cap);
        // partial top-k (shared with the lattice/PKM selection) instead
        // of sorting the entire vocab per mask position: O(V + k log k)
        masks.push(MaskPrediction::Scores(
            crate::util::topk::top_k_indices_f32(scores, k)
                .into_iter()
                .map(|i| TokenScore {
                    token: bpe.vocab.token(i as i32).to_string(),
                    logprob: scores[i] as f64,
                })
                .collect(),
        ));
    }
    PredictResponse { masks, latency_ms: 0.0, batch_size }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::BpeTrainer;

    fn bpe() -> Bpe {
        let mut t = BpeTrainer::new();
        t.add_text("the cat sat on the mat the cat sat");
        t.train(100)
    }

    #[test]
    fn retry_after_grows_with_queue_depth_and_stays_bounded() {
        // the adaptive estimate behind the Retry-After header: deeper
        // queues must tell clients to back off longer
        let mean_ms = 80.0;
        let shallow = estimate_retry_after(8, 4, mean_ms);
        let mid = estimate_retry_after(128, 4, mean_ms);
        let deep = estimate_retry_after(2048, 4, mean_ms);
        assert!(shallow < mid && mid < deep, "{shallow} < {mid} < {deep} expected");
        // exact shape: ceil(depth/capacity) batches x mean seconds
        assert_eq!(mid, (128u64.div_ceil(4) as f64 * 0.08).ceil() as u64);
        // floors and caps: never 0 (it must still mean "back off"),
        // never past a minute, sane before any execution history exists
        assert_eq!(estimate_retry_after(0, 4, mean_ms), 1);
        assert_eq!(estimate_retry_after(100, 4, 0.0), 1);
        assert_eq!(estimate_retry_after(10_000_000, 4, mean_ms), MAX_RETRY_AFTER_SECS);
        // a zero capacity (backend not built yet) must not divide by zero
        assert_eq!(estimate_retry_after(16, 0, mean_ms), 2);
    }

    #[test]
    fn encode_with_masks_finds_positions() {
        let b = bpe();
        let (ids, masks) = encode_with_masks(&b, "the [MASK] sat on the [MASK]");
        assert_eq!(masks.len(), 2);
        for &m in &masks {
            assert_eq!(ids[m], MASK_ID);
        }
        assert_eq!(ids[0], CLS_ID);
        assert_eq!(*ids.last().unwrap(), SEP_ID);
    }

    #[test]
    fn no_mask_text_has_no_positions() {
        let b = bpe();
        let (_, masks) = encode_with_masks(&b, "the cat sat");
        assert!(masks.is_empty());
    }

    #[test]
    fn truncated_mask_position_becomes_explicit_error() {
        let b = bpe();
        let (reply, _rx) = channel();
        let p = Pending {
            tokens: vec![CLS_ID, 5, MASK_ID, SEP_ID],
            mask_positions: vec![2, 9], // 9 is beyond seq_len 4
            top_k: 2,
            reply,
            enqueued: Instant::now(),
        };
        let vocab = b.vocab_size();
        let logp = vec![-1.0f32; 4 * vocab];
        let resp = extract_predictions(&logp, 0, 4, vocab, &p, &b, 5, 1);
        assert_eq!(resp.masks.len(), 2);
        assert!(resp.masks[0].scores().is_some());
        match resp.masks[1] {
            MaskPrediction::Truncated { position, seq_len } => {
                assert_eq!(position, 9);
                assert_eq!(seq_len, 4);
            }
            _ => panic!("expected truncation error"),
        }
    }
}
