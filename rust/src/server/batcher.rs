//! Dynamic batcher: coalesce concurrent fill-mask requests into one
//! inference-backend batch (max-batch-or-timeout policy, the same shape
//! as vLLM's router loop).  The backend behind the batch is pluggable
//! ([`super::backend::InferenceBackend`]): the AOT PJRT artifact or the
//! pure-rust lattice engine.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::mlm::fit_length;
use crate::tokenizer::{Bpe, CLS_ID, MASK_ID, SEP_ID};

use super::api::{MaskPrediction, PredictRequest, PredictResponse, TokenScore};
use super::backend::BackendInit;

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max time a request waits for batch-mates.
    pub max_wait: Duration,
    pub top_k_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_wait: Duration::from_millis(20), top_k_cap: 20 }
    }
}

struct Pending {
    tokens: Vec<i32>,
    mask_positions: Vec<usize>,
    top_k: usize,
    reply: Sender<Result<PredictResponse>>,
    enqueued: Instant,
}

/// The batcher: submit() from any thread; a scheduler thread drains the
/// queue into backend-sized batches.
pub struct Batcher {
    tx: Sender<Pending>,
    /// rolling access statistics (Table-5 style observability in serving)
    pub stats: Arc<Mutex<BatchStats>>,
}

#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    pub requests: u64,
    pub batches: u64,
    /// sum of true request latencies (enqueue → reply) over `requests`
    pub total_request_latency_ms: f64,
    /// sum of backend execution time over `batches`
    pub total_exec_latency_ms: f64,
    pub max_batch_fill: usize,
    /// masks reported as truncated (explicit per-mask errors)
    pub truncated_masks: u64,
    /// backend name ("artifact" / "engine")
    pub backend: &'static str,
    /// id of the checkpoint the backend serves, when restored from one
    pub checkpoint: Option<String>,
    /// value-table observability from engine-owned backends (last poll)
    pub memory_utilization: Option<f64>,
    pub memory_kl: Option<f64>,
}

impl Batcher {
    /// Spawn the scheduler/executor thread.  Blocks until the backend is
    /// constructed (or construction fails).  The backend is built *on*
    /// the executor thread — PJRT handles are not `Send`, and the engine
    /// backend's scratch has no reason to cross threads either.
    pub fn spawn(init: BackendInit, bpe: Arc<Bpe>, cfg: BatcherConfig) -> Result<Arc<Batcher>> {
        let (tx, rx): (Sender<Pending>, Receiver<Pending>) = channel();
        let stats = Arc::new(Mutex::new(BatchStats::default()));
        let batcher = Arc::new(Batcher { tx, stats: stats.clone() });
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        std::thread::spawn(move || {
            let mut backend = match init.build(&bpe) {
                Ok(b) => {
                    let mut s = stats.lock().unwrap();
                    s.backend = b.name();
                    s.checkpoint = b.checkpoint_id().map(str::to_string);
                    drop(s);
                    let _ = ready_tx.send(Ok(()));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let b_max = backend.max_batch();
            let seq_len = backend.seq_len();
            let vocab = backend.vocab();
            loop {
                // block for the first request, then collect until full or
                // the oldest request exceeds max_wait
                let first = match rx.recv() {
                    Ok(p) => p,
                    Err(_) => return, // all senders dropped: shut down
                };
                let mut group = vec![first];
                let deadline = group[0].enqueued + cfg.max_wait;
                while group.len() < b_max {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(p) => group.push(p),
                        Err(_) => break,
                    }
                }
                let t0 = Instant::now();
                let fill = group.len();
                // ragged batch: exactly the filled rows, no padding —
                // backends own their shape requirements
                let mut tokens = Vec::with_capacity(fill * seq_len);
                for p in &group {
                    tokens.extend(fit_length(p.tokens.clone(), seq_len));
                }
                let result = backend.infer(&tokens);
                let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
                {
                    let mut s = stats.lock().unwrap();
                    s.requests += fill as u64;
                    s.batches += 1;
                    s.total_exec_latency_ms += exec_ms;
                    s.max_batch_fill = s.max_batch_fill.max(fill);
                    if let Some((util, kl)) = backend.memory_stats() {
                        s.memory_utilization = Some(util);
                        s.memory_kl = Some(kl);
                    }
                }
                match result {
                    Ok(logp) => {
                        let mut latency_sum = 0.0;
                        let mut truncated = 0u64;
                        for (row, p) in group.into_iter().enumerate() {
                            let mut resp = extract_predictions(
                                &logp, row, seq_len, vocab, &p, &bpe, cfg.top_k_cap, fill,
                            );
                            truncated +=
                                resp.masks.iter().filter(|m| m.is_truncated()).count() as u64;
                            // true request latency: enqueue → reply, so
                            // queueing and batch collection are included
                            let latency = p.enqueued.elapsed().as_secs_f64() * 1e3;
                            resp.latency_ms = latency;
                            latency_sum += latency;
                            let _ = p.reply.send(Ok(resp));
                        }
                        let mut s = stats.lock().unwrap();
                        s.total_request_latency_ms += latency_sum;
                        s.truncated_masks += truncated;
                    }
                    Err(e) => {
                        let msg = format!("inference failed: {e:#}");
                        // failed requests still count toward the latency
                        // mean (`requests` was already incremented above)
                        let mut latency_sum = 0.0;
                        for p in group {
                            latency_sum += p.enqueued.elapsed().as_secs_f64() * 1e3;
                            let _ = p.reply.send(Err(anyhow!(msg.clone())));
                        }
                        stats.lock().unwrap().total_request_latency_ms += latency_sum;
                    }
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during setup"))??;
        Ok(batcher)
    }

    /// Resolve a `--backend artifact | engine | auto` flag into a
    /// spawned batcher (shared by `lram serve` and the serving example).
    ///
    /// When `checkpoint` is set, the engine path serves *trained*
    /// weights from that directory.  Without one, `--backend engine`
    /// requires the explicit `random_init` opt-in (seed weights are for
    /// tests, benches and demos — serving them by accident would look
    /// exactly like a trained model with terrible predictions).  `auto`
    /// prefers checkpoint > artifact > seed engine (with a loud warning
    /// on the last fallback).
    pub fn spawn_for_flag(
        flag: &str,
        artifact: super::backend::ArtifactInit,
        engine: super::backend::EngineConfig,
        checkpoint: Option<super::backend::CheckpointInit>,
        random_init: bool,
        bpe: Arc<Bpe>,
        cfg: BatcherConfig,
    ) -> Result<Arc<Batcher>> {
        let engine_init = |random_ok: bool| -> Result<BackendInit> {
            match (&checkpoint, random_ok) {
                (Some(ck), _) => Ok(BackendInit::EngineCheckpoint(ck.clone())),
                (None, true) => Ok(BackendInit::Engine(engine.clone())),
                (None, false) => Err(anyhow!(
                    "the engine backend serves trained weights from a checkpoint; \
                     pass --checkpoint DIR, or --random-init to explicitly serve \
                     deterministic untrained seed weights"
                )),
            }
        };
        match flag {
            "artifact" => {
                // an *engine* checkpoint cannot drive the artifact
                // executor; ignoring it would serve different weights
                // than the operator just asked for
                if checkpoint.is_some() {
                    return Err(anyhow!(
                        "--checkpoint points at an engine checkpoint directory, which \
                         --backend artifact cannot serve; use --backend engine (or auto)"
                    ));
                }
                Self::spawn(BackendInit::Artifact(artifact), bpe, cfg)
            }
            "engine" => Self::spawn(engine_init(random_init)?, bpe, cfg),
            "auto" => {
                if checkpoint.is_some() {
                    return Self::spawn(engine_init(random_init)?, bpe, cfg);
                }
                match Self::spawn(BackendInit::Artifact(artifact), bpe.clone(), cfg.clone()) {
                    Ok(b) => Ok(b),
                    Err(e) => {
                        log::warn!(
                            "artifact backend unavailable ({e:#}); serving the pure-rust \
                             engine backend with UNTRAINED seed weights — train and pass \
                             --checkpoint DIR for a real model"
                        );
                        Self::spawn(BackendInit::Engine(engine.clone()), bpe, cfg)
                    }
                }
            }
            other => Err(anyhow!("unknown backend '{other}' (use artifact | engine | auto)")),
        }
    }

    /// Tokenize + enqueue a request; blocks until the response is ready.
    pub fn submit(&self, bpe: &Bpe, req: &PredictRequest) -> Result<PredictResponse> {
        let (tokens, mask_positions) = encode_with_masks(bpe, &req.text);
        if mask_positions.is_empty() {
            return Err(anyhow!("request contains no [MASK] token"));
        }
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Pending {
                tokens,
                mask_positions,
                top_k: req.top_k,
                reply: reply_tx,
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow!("batcher is shut down"))?;
        reply_rx.recv().map_err(|_| anyhow!("batcher dropped the request"))?
    }
}

/// Tokenize text, mapping literal `[MASK]` spans to the mask id.
pub fn encode_with_masks(bpe: &Bpe, text: &str) -> (Vec<i32>, Vec<usize>) {
    let mut ids = vec![CLS_ID];
    let mut masks = Vec::new();
    let mut rest = text;
    while let Some(pos) = rest.find("[MASK]") {
        ids.extend(bpe.encode(&rest[..pos]));
        masks.push(ids.len());
        ids.push(MASK_ID);
        rest = &rest[pos + "[MASK]".len()..];
    }
    ids.extend(bpe.encode(rest));
    ids.push(SEP_ID);
    (ids, masks)
}

#[allow(clippy::too_many_arguments)]
fn extract_predictions(
    logp: &[f32],
    row: usize,
    seq_len: usize,
    vocab: usize,
    p: &Pending,
    bpe: &Bpe,
    top_k_cap: usize,
    batch_size: usize,
) -> PredictResponse {
    let mut masks = Vec::with_capacity(p.mask_positions.len());
    for &pos in &p.mask_positions {
        if pos >= seq_len {
            // the mask fell off the fixed-length batch row: surface an
            // explicit error, never a silent empty prediction
            masks.push(MaskPrediction::Truncated { position: pos, seq_len });
            continue;
        }
        let base = row * seq_len * vocab + pos * vocab;
        let scores = &logp[base..base + vocab];
        let k = p.top_k.min(top_k_cap);
        // partial top-k (shared with the lattice/PKM selection) instead
        // of sorting the entire vocab per mask position: O(V + k log k)
        masks.push(MaskPrediction::Scores(
            crate::util::topk::top_k_indices_f32(scores, k)
                .into_iter()
                .map(|i| TokenScore {
                    token: bpe.vocab.token(i as i32).to_string(),
                    logprob: scores[i] as f64,
                })
                .collect(),
        ));
    }
    PredictResponse { masks, latency_ms: 0.0, batch_size }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::BpeTrainer;

    fn bpe() -> Bpe {
        let mut t = BpeTrainer::new();
        t.add_text("the cat sat on the mat the cat sat");
        t.train(100)
    }

    #[test]
    fn encode_with_masks_finds_positions() {
        let b = bpe();
        let (ids, masks) = encode_with_masks(&b, "the [MASK] sat on the [MASK]");
        assert_eq!(masks.len(), 2);
        for &m in &masks {
            assert_eq!(ids[m], MASK_ID);
        }
        assert_eq!(ids[0], CLS_ID);
        assert_eq!(*ids.last().unwrap(), SEP_ID);
    }

    #[test]
    fn no_mask_text_has_no_positions() {
        let b = bpe();
        let (_, masks) = encode_with_masks(&b, "the cat sat");
        assert!(masks.is_empty());
    }

    #[test]
    fn truncated_mask_position_becomes_explicit_error() {
        let b = bpe();
        let (reply, _rx) = channel();
        let p = Pending {
            tokens: vec![CLS_ID, 5, MASK_ID, SEP_ID],
            mask_positions: vec![2, 9], // 9 is beyond seq_len 4
            top_k: 2,
            reply,
            enqueued: Instant::now(),
        };
        let vocab = b.vocab_size();
        let logp = vec![-1.0f32; 4 * vocab];
        let resp = extract_predictions(&logp, 0, 4, vocab, &p, &b, 5, 1);
        assert_eq!(resp.masks.len(), 2);
        assert!(resp.masks[0].scores().is_some());
        match resp.masks[1] {
            MaskPrediction::Truncated { position, seq_len } => {
                assert_eq!(position, 9);
                assert_eq!(seq_len, 4);
            }
            _ => panic!("expected truncation error"),
        }
    }
}
