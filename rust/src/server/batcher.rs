//! Dynamic batcher: coalesce concurrent fill-mask requests into the
//! fixed-shape inference artifact (max-batch-or-timeout policy, the same
//! shape as vLLM's router loop).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::mlm::fit_length;
use crate::runtime::{ArtifactState, HostTensor, Runtime};
use crate::tokenizer::{Bpe, CLS_ID, MASK_ID, SEP_ID};

use super::api::{PredictRequest, PredictResponse, TokenScore};

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max time a request waits for batch-mates.
    pub max_wait: Duration,
    pub top_k_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_wait: Duration::from_millis(20), top_k_cap: 20 }
    }
}

struct Pending {
    tokens: Vec<i32>,
    mask_positions: Vec<usize>,
    top_k: usize,
    reply: Sender<Result<PredictResponse>>,
    enqueued: Instant,
}

/// The batcher: submit() from any thread; a scheduler thread drains the
/// queue into artifact-sized batches.
pub struct Batcher {
    tx: Sender<Pending>,
    /// rolling access statistics (Table-5 style observability in serving)
    pub stats: Arc<Mutex<BatchStats>>,
}

#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency_ms: f64,
    pub max_batch_fill: usize,
}

/// Everything the executor thread needs to construct its own PJRT state —
/// the xla crate's handles are not Send, so the thread owns the runtime.
#[derive(Debug, Clone)]
pub struct BatcherInit {
    pub artifact_dir: String,
    pub artifact_name: String,
    pub checkpoint: Option<Vec<u8>>,
}

impl Batcher {
    /// Spawn the scheduler/executor thread.  Blocks until the artifact is
    /// compiled (or compilation fails).
    pub fn spawn(init: BatcherInit, bpe: Arc<Bpe>, cfg: BatcherConfig) -> Result<Arc<Batcher>> {
        let (tx, rx): (Sender<Pending>, Receiver<Pending>) = channel();
        let stats = Arc::new(Mutex::new(BatchStats::default()));
        let batcher = Arc::new(Batcher { tx, stats: stats.clone() });
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        std::thread::spawn(move || {
            // the PJRT client, executable and state all live (and die) on
            // this thread
            let setup = (|| -> Result<_> {
                let rt = Runtime::new(&init.artifact_dir)?;
                let artifact = rt.load(&init.artifact_name)?;
                let state = match &init.checkpoint {
                    Some(bytes) => ArtifactState::from_bytes(&artifact.manifest, bytes)?,
                    None => artifact.initial_state()?,
                };
                Ok((rt, artifact, state))
            })();
            let (_rt, artifact, mut state) = match setup {
                Ok(v) => {
                    let _ = ready_tx.send(Ok(()));
                    v
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let b_max = artifact.manifest.batch.b;
            let seq_len = artifact.manifest.inputs[0].shape[1];
            let vocab =
                artifact.manifest.outputs[artifact.manifest.n_state_outputs].shape[2];
            loop {
                // block for the first request, then collect until full or
                // the oldest request exceeds max_wait
                let first = match rx.recv() {
                    Ok(p) => p,
                    Err(_) => return, // all senders dropped: shut down
                };
                let mut group = vec![first];
                let deadline = group[0].enqueued + cfg.max_wait;
                while group.len() < b_max {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(p) => group.push(p),
                        Err(_) => break,
                    }
                }
                let t0 = Instant::now();
                let fill = group.len();
                // build the fixed-shape batch (pad with empty rows)
                let mut tokens = Vec::with_capacity(b_max * seq_len);
                for p in &group {
                    tokens.extend(fit_length(p.tokens.clone(), seq_len));
                }
                for _ in group.len()..b_max {
                    tokens.extend(std::iter::repeat(0).take(seq_len));
                }
                let inputs = vec![HostTensor::I32(tokens, vec![b_max, seq_len])];
                let result = artifact.call(&mut state, &inputs);
                let latency = t0.elapsed().as_secs_f64() * 1e3;
                {
                    let mut s = stats.lock().unwrap();
                    s.requests += fill as u64;
                    s.batches += 1;
                    s.total_latency_ms += latency;
                    s.max_batch_fill = s.max_batch_fill.max(fill);
                }
                match result {
                    Ok(outs) => {
                        let logp = outs[0].as_f32().unwrap_or(&[]).to_vec();
                        for (row, p) in group.into_iter().enumerate() {
                            let resp = extract_predictions(
                                &logp, row, seq_len, vocab, &p, &bpe, cfg.top_k_cap,
                                latency, fill,
                            );
                            let _ = p.reply.send(Ok(resp));
                        }
                    }
                    Err(e) => {
                        let msg = format!("inference failed: {e:#}");
                        for p in group {
                            let _ = p.reply.send(Err(anyhow!(msg.clone())));
                        }
                    }
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during setup"))??;
        Ok(batcher)
    }

    /// Tokenize + enqueue a request; blocks until the response is ready.
    pub fn submit(&self, bpe: &Bpe, req: &PredictRequest) -> Result<PredictResponse> {
        let (tokens, mask_positions) = encode_with_masks(bpe, &req.text);
        if mask_positions.is_empty() {
            return Err(anyhow!("request contains no [MASK] token"));
        }
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Pending {
                tokens,
                mask_positions,
                top_k: req.top_k,
                reply: reply_tx,
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow!("batcher is shut down"))?;
        reply_rx.recv().map_err(|_| anyhow!("batcher dropped the request"))?
    }
}

/// Tokenize text, mapping literal `[MASK]` spans to the mask id.
pub fn encode_with_masks(bpe: &Bpe, text: &str) -> (Vec<i32>, Vec<usize>) {
    let mut ids = vec![CLS_ID];
    let mut masks = Vec::new();
    let mut rest = text;
    while let Some(pos) = rest.find("[MASK]") {
        ids.extend(bpe.encode(&rest[..pos]));
        masks.push(ids.len());
        ids.push(MASK_ID);
        rest = &rest[pos + "[MASK]".len()..];
    }
    ids.extend(bpe.encode(rest));
    ids.push(SEP_ID);
    (ids, masks)
}

#[allow(clippy::too_many_arguments)]
fn extract_predictions(
    logp: &[f32],
    row: usize,
    seq_len: usize,
    vocab: usize,
    p: &Pending,
    bpe: &Bpe,
    top_k_cap: usize,
    latency_ms: f64,
    batch_size: usize,
) -> PredictResponse {
    let mut masks = Vec::with_capacity(p.mask_positions.len());
    for &pos in &p.mask_positions {
        if pos >= seq_len {
            masks.push(vec![]);
            continue;
        }
        let base = row * seq_len * vocab + pos * vocab;
        let scores = &logp[base..base + vocab];
        let k = p.top_k.min(top_k_cap);
        // partial top-k (shared with the lattice/PKM selection) instead
        // of sorting the entire vocab per mask position: O(V + k log k)
        masks.push(
            crate::util::topk::top_k_indices_f32(scores, k)
                .into_iter()
                .map(|i| TokenScore {
                    token: bpe.vocab.token(i as i32).to_string(),
                    logprob: scores[i] as f64,
                })
                .collect(),
        );
    }
    PredictResponse { masks, latency_ms, batch_size }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::BpeTrainer;

    fn bpe() -> Bpe {
        let mut t = BpeTrainer::new();
        t.add_text("the cat sat on the mat the cat sat");
        t.train(100)
    }

    #[test]
    fn encode_with_masks_finds_positions() {
        let b = bpe();
        let (ids, masks) = encode_with_masks(&b, "the [MASK] sat on the [MASK]");
        assert_eq!(masks.len(), 2);
        for &m in &masks {
            assert_eq!(ids[m], MASK_ID);
        }
        assert_eq!(ids[0], CLS_ID);
        assert_eq!(*ids.last().unwrap(), SEP_ID);
    }

    #[test]
    fn no_mask_text_has_no_positions() {
        let b = bpe();
        let (_, masks) = encode_with_masks(&b, "the cat sat");
        assert!(masks.is_empty());
    }
}
