//! Dynamic batcher: coalesce concurrent fill-mask requests into one
//! inference-backend batch (max-batch-or-timeout policy, the same shape
//! as vLLM's router loop).  The backend behind the batch is pluggable
//! ([`super::backend::InferenceBackend`]): the AOT PJRT artifact or the
//! pure-rust lattice engine.
//!
//! The executor is *supervised*: it runs under `catch_unwind` on a
//! supervisor thread that rebuilds the backend from its init (for a
//! checkpoint-backed backend, from the last good checkpoint on disk)
//! with capped exponential backoff after a panic.  In-flight requests
//! whose reply channels die in the unwind surface as
//! [`SubmitError::Unavailable`] (503 at the front door) — never a hung
//! client — and requests still queued in the channel survive into the
//! restarted executor.  A backend that reports itself *poisoned*
//! ([`InferenceBackend::poisoned`], e.g. a contained SIGBUS on a mapped
//! value table) takes the same road without a panic: its batch is
//! answered 503 and the executor returns to the supervisor for a
//! rebuild.  The supervisor exports the
//! `starting → ready → degraded → draining` [`Health`] state machine
//! that `/healthz`, `/readyz` and `/stats` report.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::mlm::fit_length;
use crate::tokenizer::{Bpe, CLS_ID, MASK_ID, SEP_ID};
use crate::util::failpoint;
use crate::util::hist::Histogram;
use crate::util::lockcheck::{rank, Mutex, MutexGuard};

use super::api::{MaskPrediction, PredictRequest, PredictResponse, TokenScore};
use super::backend::{BackendInit, BackendStats, InferenceBackend};

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max time a request waits for batch-mates.
    pub max_wait: Duration,
    pub top_k_cap: usize,
    /// Bounded admission: max requests admitted but not yet replied to
    /// (queued + in-flight).  Submissions beyond this are shed with
    /// [`SubmitError::Overloaded`] — the HTTP layer turns that into a
    /// `429 Too Many Requests` with `Retry-After` — instead of growing
    /// an unbounded queue whose tail latency nobody survives.
    pub max_pending: usize,
    /// Per-request deadline (`--request-timeout-ms`): a request that has
    /// already waited this long when the executor dequeues it is expired
    /// with [`SubmitError::Timeout`] (504) *without touching the
    /// backend* — burning a batch slot on a reply nobody is waiting for
    /// just deepens the overload that made it late.  `None` = no
    /// deadline.
    pub request_timeout: Option<Duration>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_wait: Duration::from_millis(20),
            top_k_cap: 20,
            max_pending: 1024,
            request_timeout: None,
        }
    }
}

/// Why a submission did not produce predictions.  The split is the HTTP
/// status boundary: the front door maps `BadRequest` to 400,
/// `Overloaded` to 429 + `Retry-After`, `Unavailable` to 503 +
/// `Retry-After`, `Timeout` to 504, and `Internal` to 500.
#[derive(Debug)]
pub enum SubmitError {
    /// The request itself is invalid (e.g. no `[MASK]` token).
    BadRequest(String),
    /// The bounded admission queue is full; the request was shed
    /// *before* tokenization and never reached the backend.
    Overloaded { queue_depth: usize, max_pending: usize },
    /// The executor died (panic / restart in progress) while this
    /// request was in flight; the supervisor is restarting it from the
    /// last good state.  Transient — clients should retry.
    Unavailable(String),
    /// The request's deadline expired before the backend saw it.
    Timeout { waited_ms: u64 },
    /// The batcher or backend failed; the request was admitted but
    /// could not be answered.
    Internal(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::BadRequest(m) => write!(f, "{m}"),
            SubmitError::Overloaded { queue_depth, max_pending } => write!(
                f,
                "server overloaded: {queue_depth} requests pending (admission cap {max_pending})"
            ),
            SubmitError::Unavailable(m) => write!(f, "{m}"),
            SubmitError::Timeout { waited_ms } => write!(
                f,
                "request deadline exceeded after {waited_ms}ms in queue; \
                 the backend never saw it"
            ),
            SubmitError::Internal(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// The serving health state machine, exported by the batcher supervisor
/// and reported by `/healthz` (liveness: any state is alive), `/readyz`
/// (readiness: 200 only on `Ready`) and `/stats`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum HealthState {
    /// Backend still constructing (first boot).
    Starting = 0,
    /// Executor live, requests flowing.
    Ready = 1,
    /// Executor died; the supervisor is rebuilding it with backoff.
    Degraded = 2,
    /// Graceful shutdown: in-flight work completing, no new admissions.
    Draining = 3,
}

impl HealthState {
    pub fn as_str(self) -> &'static str {
        match self {
            HealthState::Starting => "starting",
            HealthState::Ready => "ready",
            HealthState::Degraded => "degraded",
            HealthState::Draining => "draining",
        }
    }

    fn from_u8(v: u8) -> HealthState {
        match v {
            0 => HealthState::Starting,
            1 => HealthState::Ready,
            2 => HealthState::Degraded,
            _ => HealthState::Draining,
        }
    }
}

/// Shared liveness/readiness record: the supervisor writes it, the HTTP
/// layer reads it lock-free on every `/healthz`/`/readyz`/`/stats`.
#[derive(Debug)]
pub struct Health {
    state: AtomicU8,
    restarts: AtomicU64,
}

impl Default for Health {
    fn default() -> Self {
        Health {
            state: AtomicU8::new(HealthState::Starting as u8),
            restarts: AtomicU64::new(0),
        }
    }
}

impl Health {
    pub fn state(&self) -> HealthState {
        // ORDERING: health is a monitoring snapshot — /healthz reading a
        // one-transition-stale state is indistinguishable from having
        // polled a moment earlier; no data is published through it
        HealthState::from_u8(self.state.load(Ordering::Relaxed))
    }

    /// Executor restarts since boot (0 = the executor never died).
    pub fn restarts(&self) -> u64 {
        // ORDERING: monotonic counter read for display only
        self.restarts.load(Ordering::Relaxed)
    }

    /// Enter graceful shutdown.  Draining is terminal: supervisor
    /// transitions (ready/degraded) no longer apply past this point.
    pub fn set_draining(&self) {
        // ORDERING: monitoring snapshot (see state()); the drain itself
        // is driven by channel teardown, not by this flag
        self.state.store(HealthState::Draining as u8, Ordering::Relaxed);
    }

    fn note_restart(&self) -> u64 {
        // ORDERING: monotonic counter; fetch_add's atomicity is all the
        // restart count needs
        self.restarts.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Supervisor-side transition; a concurrent drain always wins.
    fn transition(&self, to: HealthState) {
        // ORDERING: the CAS loop only needs atomicity on the one state
        // byte — "draining wins" is decided by the compare, not by any
        // cross-variable visibility
        let mut cur = self.state.load(Ordering::Relaxed);
        loop {
            if cur == HealthState::Draining as u8 {
                return;
            }
            match self.state.compare_exchange_weak(
                cur,
                to as u8,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }
}

/// Exactly-once release of one bounded-admission slot.  After an
/// executor panic *both* sides may try to release the same slot — the
/// executor on its normal reply path, and the submitting client when its
/// reply channel dies in the unwind — so release is guarded by a swap:
/// double-releasing would leak admission capacity permanently.
struct SlotGuard {
    pending: Arc<AtomicUsize>,
    released: AtomicBool,
}

impl SlotGuard {
    fn release(&self) {
        if !self.released.swap(true, Ordering::AcqRel) {
            self.pending.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// Callback fired when an async submission's outcome becomes readable
/// (or is guaranteed never to arrive): the event-driven front door
/// pushes the owning connection's token onto its completion queue and
/// wakes the event loop.  Must not block and must not take any lock
/// ranked at or below the batcher's (`batcher.stats`) — it runs on the
/// executor thread with no locks held.
pub type ReplyNotify = Arc<dyn Fn() + Send + Sync>;

/// The executor's reply handle.  [`ReplyTo::send`] delivers the outcome
/// *then* fires the completion notifier; dropping without sending (an
/// executor unwind mid-batch) fires the notifier too, so an
/// event-driven waiter always gets woken — it then observes the
/// disconnected channel and surfaces [`SubmitError::Unavailable`]
/// exactly like a blocked [`Batcher::submit_bounded`] caller.
struct ReplyTo {
    tx: Sender<Result<PredictResponse, SubmitError>>,
    notify: Option<ReplyNotify>,
}

impl ReplyTo {
    /// Deliver the outcome and wake the waiter.  Consumes the handle so
    /// the notifier fires exactly once (the `Drop` impl only fires if
    /// `send` never ran).
    fn send(mut self, outcome: Result<PredictResponse, SubmitError>) {
        let _ = self.tx.send(outcome);
        if let Some(n) = self.notify.take() {
            n();
        }
    }
}

impl Drop for ReplyTo {
    fn drop(&mut self) {
        if let Some(n) = self.notify.take() {
            n();
        }
    }
}

struct Pending {
    tokens: Vec<i32>,
    mask_positions: Vec<usize>,
    top_k: usize,
    reply: ReplyTo,
    enqueued: Instant,
    /// Hard deadline derived from [`BatcherConfig::request_timeout`].
    deadline: Option<Instant>,
    /// Shared with the submitting client (see [`SlotGuard`]).
    slot: Arc<SlotGuard>,
}

/// The batcher: submit() from any thread; a supervised executor thread
/// drains the queue into backend-sized batches.  Admission is bounded:
/// at most `max_pending` requests may be queued or in flight at once,
/// the rest are shed at the door.
pub struct Batcher {
    tx: Sender<Pending>,
    /// requests admitted but not yet replied to (queued + in-flight);
    /// incremented at admission, decremented exactly once per request
    /// via its [`SlotGuard`]
    pending: Arc<AtomicUsize>,
    max_pending: usize,
    /// per-request deadline handed to every submission (see
    /// [`BatcherConfig::request_timeout`])
    request_timeout: Option<Duration>,
    /// the backend's max batch rows (set once the executor builds it);
    /// sizes the adaptive `Retry-After` estimate
    batch_capacity: Arc<AtomicUsize>,
    /// liveness/readiness exported by the supervisor
    health: Arc<Health>,
    /// rolling access statistics (Table-5 style observability in serving)
    pub stats: Arc<Mutex<BatchStats>>,
}

#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    pub requests: u64,
    pub batches: u64,
    /// sum of true request latencies (enqueue → reply) over `requests`
    pub total_request_latency_ms: f64,
    /// sum of backend execution time over `batches`
    pub total_exec_latency_ms: f64,
    pub max_batch_fill: usize,
    /// masks reported as truncated (explicit per-mask errors)
    pub truncated_masks: u64,
    /// requests shed at admission (bounded queue full, 429 to clients);
    /// shed requests never reach the backend and are not in `requests`
    pub shed: u64,
    /// requests whose deadline expired in the queue (504 to clients);
    /// like sheds they never reach the backend and are not in `requests`
    pub timeouts: u64,
    /// request latency distribution (enqueue → reply), for p50/p95/p99
    /// in `/stats`
    pub latency: Histogram,
    /// backend name ("artifact" / "engine")
    pub backend: &'static str,
    /// id of the checkpoint the backend serves, when restored from one
    pub checkpoint: Option<String>,
    /// value-table observability from engine-owned backends (last poll):
    /// whole-table utilization/KL plus the per-shard breakdown
    pub memory: Option<BackendStats>,
}

/// Lock the batch stats, recovering from poisoning.  The executor is
/// supervised — a `panic`-action failpoint (or a real bug) can unwind
/// while this lock is held; the fields are plain counters, so the worst
/// a poisoned guard hides is one torn increment, which is strictly
/// better than every future `/stats` reader and reply path panicking.
fn lock_stats(stats: &Mutex<BatchStats>) -> MutexGuard<'_, BatchStats> {
    stats.lock().unwrap_or_else(|p| p.into_inner())
}

/// First restart delay after an executor panic.
const RESTART_BACKOFF_BASE: Duration = Duration::from_millis(100);
/// Backoff ceiling for a persistently-crashing backend.
const RESTART_BACKOFF_CAP: Duration = Duration::from_secs(5);

impl Batcher {
    /// Spawn the supervisor + executor thread.  Blocks until the backend
    /// is constructed (or first-boot construction fails).  The backend
    /// is built *on* the executor thread — PJRT handles are not `Send`,
    /// and the engine backend's scratch has no reason to cross threads
    /// either — and is *re*built there from the same init after a panic,
    /// so a checkpoint-backed backend restarts from the last good
    /// checkpoint on disk.
    pub fn spawn(init: BackendInit, bpe: Arc<Bpe>, cfg: BatcherConfig) -> Result<Arc<Batcher>> {
        let (tx, rx): (Sender<Pending>, Receiver<Pending>) = channel();
        let stats = Arc::new(Mutex::new(rank::BATCH_STATS, BatchStats::default()));
        let pending = Arc::new(AtomicUsize::new(0));
        let batch_capacity = Arc::new(AtomicUsize::new(1));
        let health = Arc::new(Health::default());
        let batcher = Arc::new(Batcher {
            tx,
            pending: pending.clone(),
            max_pending: cfg.max_pending,
            request_timeout: cfg.request_timeout,
            batch_capacity: batch_capacity.clone(),
            health: health.clone(),
            stats: stats.clone(),
        });
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        std::thread::spawn(move || {
            supervise(init, bpe, cfg, rx, stats, batch_capacity, health, ready_tx)
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during setup"))??;
        Ok(batcher)
    }

    /// The liveness/readiness record the supervisor maintains.
    pub fn health(&self) -> &Health {
        &self.health
    }

    /// Owned handle to the same record, for threads (signal watcher,
    /// shutdown path) that outlive a borrow of the batcher.
    pub fn health_handle(&self) -> Arc<Health> {
        self.health.clone()
    }

    /// Clone the rolling stats under the poison-recovering lock.
    pub fn stats_snapshot(&self) -> BatchStats {
        lock_stats(&self.stats).clone()
    }

    /// Resolve a `--backend artifact | engine | auto` flag into a
    /// spawned batcher (shared by `lram serve` and the serving example).
    ///
    /// When `checkpoint` is set, the engine path serves *trained*
    /// weights from that directory.  Without one, `--backend engine`
    /// requires the explicit `random_init` opt-in (seed weights are for
    /// tests, benches and demos — serving them by accident would look
    /// exactly like a trained model with terrible predictions).  `auto`
    /// prefers checkpoint > artifact > seed engine (with a loud warning
    /// on the last fallback).
    pub fn spawn_for_flag(
        flag: &str,
        artifact: super::backend::ArtifactInit,
        engine: super::backend::EngineConfig,
        checkpoint: Option<super::backend::CheckpointInit>,
        random_init: bool,
        bpe: Arc<Bpe>,
        cfg: BatcherConfig,
    ) -> Result<Arc<Batcher>> {
        let engine_init = |random_ok: bool| -> Result<BackendInit> {
            match (&checkpoint, random_ok) {
                (Some(ck), _) => Ok(BackendInit::EngineCheckpoint(ck.clone())),
                (None, true) => Ok(BackendInit::Engine(engine.clone())),
                (None, false) => Err(anyhow!(
                    "the engine backend serves trained weights from a checkpoint; \
                     pass --checkpoint DIR, or --random-init to explicitly serve \
                     deterministic untrained seed weights"
                )),
            }
        };
        match flag {
            "artifact" => {
                // an *engine* checkpoint cannot drive the artifact
                // executor; ignoring it would serve different weights
                // than the operator just asked for
                if checkpoint.is_some() {
                    return Err(anyhow!(
                        "--checkpoint points at an engine checkpoint directory, which \
                         --backend artifact cannot serve; use --backend engine (or auto)"
                    ));
                }
                Self::spawn(BackendInit::Artifact(artifact), bpe, cfg)
            }
            "engine" => Self::spawn(engine_init(random_init)?, bpe, cfg),
            "auto" => {
                if checkpoint.is_some() {
                    return Self::spawn(engine_init(random_init)?, bpe, cfg);
                }
                match Self::spawn(BackendInit::Artifact(artifact), bpe.clone(), cfg.clone()) {
                    Ok(b) => Ok(b),
                    Err(e) => {
                        log::warn!(
                            "artifact backend unavailable ({e:#}); serving the pure-rust \
                             engine backend with UNTRAINED seed weights — train and pass \
                             --checkpoint DIR for a real model"
                        );
                        Self::spawn(BackendInit::Engine(engine.clone()), bpe, cfg)
                    }
                }
            }
            other => Err(anyhow!("unknown backend '{other}' (use artifact | engine | auto)")),
        }
    }

    /// Requests admitted but not yet replied to (queued + in-flight).
    pub fn queue_depth(&self) -> usize {
        // ORDERING: observability read; the admission path re-reads the
        // counter under its own CAS, so staleness here cannot oversubscribe
        self.pending.load(Ordering::Relaxed)
    }

    /// The bounded-admission cap this batcher sheds beyond.
    pub fn max_pending(&self) -> usize {
        self.max_pending
    }

    /// Suggested client back-off for shed responses, estimated from the
    /// live queue depth and the measured mean batch execution latency
    /// (ROADMAP PR-4 "Adaptive Retry-After": a well-behaved client
    /// should back off proportionally to actual overload, not a
    /// constant).  Clients see this as the `Retry-After` header on
    /// every 429.
    pub fn retry_after_secs(&self) -> u64 {
        let mean_batch_ms = {
            let s = lock_stats(&self.stats);
            if s.batches > 0 { s.total_exec_latency_ms / s.batches as f64 } else { 0.0 }
        };
        estimate_retry_after(
            self.queue_depth(),
            // ORDERING: capacity is written once at backend build; a
            // stale read only skews the Retry-After estimate by a batch
            self.batch_capacity.load(Ordering::Relaxed),
            mean_batch_ms,
        )
    }

    /// Tokenize + enqueue a request; blocks until the response is ready.
    /// Convenience wrapper over [`Self::submit_bounded`] that flattens
    /// the typed error (tests and non-HTTP callers).
    pub fn submit(&self, bpe: &Bpe, req: &PredictRequest) -> Result<PredictResponse> {
        self.submit_bounded(bpe, req).map_err(anyhow::Error::from)
    }

    /// Tokenize + enqueue a request under bounded admission; blocks
    /// until the response is ready or the request is shed.
    ///
    /// Admission is checked *first* — shedding under overload must be
    /// the cheapest path through this function, and a shed request
    /// never reaches the backend (it is not even tokenized).
    pub fn submit_bounded(
        &self,
        bpe: &Bpe,
        req: &PredictRequest,
    ) -> Result<PredictResponse, SubmitError> {
        let pending = self.enqueue(bpe, req, None)?;
        // the executor owns the slot now: it releases after replying, so
        // queue depth counts in-flight work, not just the channel
        match pending.rx.recv() {
            Ok(outcome) => outcome,
            Err(_) => {
                // the executor unwound with this request in flight and
                // never replied; reclaim the slot ourselves (idempotent
                // if the executor got to it first) and tell the client
                // the truth: transient, retry
                pending.slot.release();
                Err(SubmitError::Unavailable(EXECUTOR_DIED_MSG.into()))
            }
        }
    }

    /// [`Self::submit_bounded`] without the blocking wait: tokenize +
    /// enqueue under the same bounded admission, returning immediately
    /// with a [`PendingReply`] the caller polls via
    /// [`PendingReply::try_take`].  `notify` fires (from the executor
    /// thread, exactly once) when an outcome becomes readable — or when
    /// it is guaranteed never to arrive, in which case `try_take`
    /// reports [`SubmitError::Unavailable`].  The event-driven front
    /// door parks the connection on this instead of parking a thread.
    pub fn submit_bounded_async(
        &self,
        bpe: &Bpe,
        req: &PredictRequest,
        notify: ReplyNotify,
    ) -> Result<PendingReply, SubmitError> {
        self.enqueue(bpe, req, Some(notify))
    }

    /// Shared admission + enqueue path behind [`Self::submit_bounded`]
    /// and [`Self::submit_bounded_async`].
    ///
    /// Admission is checked *first* — shedding under overload must be
    /// the cheapest path through this function, and a shed request
    /// never reaches the backend (it is not even tokenized).
    fn enqueue(
        &self,
        bpe: &Bpe,
        req: &PredictRequest,
        notify: Option<ReplyNotify>,
    ) -> Result<PendingReply, SubmitError> {
        // fault site for the admission path itself (chaos harness)
        if let Some(e) = failpoint::inject("batcher.submit") {
            return Err(SubmitError::Internal(format!("{e:#}")));
        }
        // claim an admission slot (lock-free; contended only at the cap)
        // ORDERING: relaxed initial read + relaxed CAS-failure reload are
        // fine — the AcqRel success is what claims the slot, and a stale
        // first read just costs one extra CAS iteration
        let mut cur = self.pending.load(Ordering::Relaxed);
        loop {
            if cur >= self.max_pending {
                lock_stats(&self.stats).shed += 1;
                return Err(SubmitError::Overloaded {
                    queue_depth: cur,
                    max_pending: self.max_pending,
                });
            }
            match self.pending.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        // the guard is shared with the executor: whoever reaches a
        // terminal outcome for this request first releases the slot,
        // exactly once (see SlotGuard)
        let slot = Arc::new(SlotGuard {
            pending: self.pending.clone(),
            released: AtomicBool::new(false),
        });
        let (tokens, mask_positions) = encode_with_masks(bpe, &req.text);
        if mask_positions.is_empty() {
            slot.release();
            return Err(SubmitError::BadRequest("request contains no [MASK] token".into()));
        }
        let (reply_tx, reply_rx) = channel();
        let enqueued = Instant::now();
        let sent = self.tx.send(Pending {
            tokens,
            mask_positions,
            top_k: req.top_k,
            reply: ReplyTo { tx: reply_tx, notify },
            enqueued,
            deadline: self.request_timeout.map(|t| enqueued + t),
            slot: slot.clone(),
        });
        if sent.is_err() {
            slot.release();
            return Err(SubmitError::Internal("batcher is shut down".into()));
        }
        Ok(PendingReply { rx: reply_rx, slot })
    }
}

/// What a blocked client is told when the executor unwound with its
/// request in flight (same wording on the blocking and async paths).
const EXECUTOR_DIED_MSG: &str = "the inference executor failed mid-request and is being \
     restarted from its last good state; retry shortly";

/// An admitted request awaiting its outcome — the async counterpart of
/// the blocking wait inside [`Batcher::submit_bounded`].  Holds the
/// reply channel plus the admission [`SlotGuard`] so an abandoned
/// executor (unwind without reply) still frees the slot.
pub struct PendingReply {
    rx: Receiver<Result<PredictResponse, SubmitError>>,
    slot: Arc<SlotGuard>,
}

impl PendingReply {
    /// Non-blocking poll for the outcome.  `None` = still in flight
    /// (spurious wakes are fine — poll again on the next notify).  A
    /// disconnected channel (the executor unwound without replying)
    /// releases the admission slot and reports
    /// [`SubmitError::Unavailable`], exactly like the blocking path.
    pub fn try_take(&self) -> Option<Result<PredictResponse, SubmitError>> {
        match self.rx.try_recv() {
            Ok(outcome) => Some(outcome),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                self.slot.release();
                Some(Err(SubmitError::Unavailable(EXECUTOR_DIED_MSG.into())))
            }
        }
    }
}

/// Supervisor body: build (or re-build) the backend, run the executor
/// under `catch_unwind`, and on a panic restart it with capped
/// exponential backoff.  Runs on its own thread for the life of the
/// [`Batcher`]; exits when every submit handle is gone (channel
/// disconnect) or first-boot construction fails.
#[allow(clippy::too_many_arguments)]
fn supervise(
    init: BackendInit,
    bpe: Arc<Bpe>,
    cfg: BatcherConfig,
    rx: Receiver<Pending>,
    stats: Arc<Mutex<BatchStats>>,
    batch_capacity: Arc<AtomicUsize>,
    health: Arc<Health>,
    ready_tx: Sender<Result<()>>,
) {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    // Some(_) until the first boot resolves: the spawn() caller is
    // blocked on this handshake and deserves a hard error, not a silent
    // retry loop, if the backend cannot be built at all
    let mut ready_tx = Some(ready_tx);
    let mut backoff = RESTART_BACKOFF_BASE;
    loop {
        let built = catch_unwind(AssertUnwindSafe(|| init.build(&bpe)))
            .unwrap_or_else(|_| Err(anyhow!("backend construction panicked")));
        let backend = match built {
            Ok(b) => b,
            Err(e) => match ready_tx.take() {
                Some(t) => {
                    let _ = t.send(Err(e));
                    return;
                }
                None => {
                    log::error!(
                        "backend rebuild failed ({e:#}); next attempt in {backoff:?} \
                         (serving stays degraded)"
                    );
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(RESTART_BACKOFF_CAP);
                    continue;
                }
            },
        };
        {
            let mut s = lock_stats(&stats);
            s.backend = backend.name();
            s.checkpoint = backend.checkpoint_id().map(str::to_string);
        }
        // ORDERING: single-writer capacity hint consumed by the
        // Retry-After estimate; no other state rides on its visibility
        batch_capacity.store(backend.max_batch().max(1), Ordering::Relaxed);
        if let Some(t) = ready_tx.take() {
            let _ = t.send(Ok(()));
        }
        health.transition(HealthState::Ready);
        let batches_before = lock_stats(&stats).batches;
        let run =
            catch_unwind(AssertUnwindSafe(|| executor_loop(&rx, backend, &bpe, &cfg, &stats)));
        let why = match run {
            // channel disconnected: every submit handle dropped, clean
            // shutdown of the whole supervisor
            Ok(ExecutorExit::Shutdown) => return,
            // the executor returned the backend voluntarily: its memory
            // is known-corrupt (contained SIGBUS on a mapped blob); its
            // final batch was already answered 503
            Ok(ExecutorExit::Poisoned) => {
                "backend memory poisoned (SIGBUS on a mapped blob, contained)"
            }
            // the panic unwound the executor: its in-flight group's
            // reply senders are gone (clients see Unavailable → 503
            // and release their own slots); requests still queued in
            // the channel survive into the restarted executor
            Err(_) => "batcher executor panicked",
        };
        health.transition(HealthState::Degraded);
        let restarts = health.note_restart();
        // a backend that served real batches since the last
        // restart has proven itself; only back off harder when
        // it crash-loops without making progress
        if lock_stats(&stats).batches > batches_before {
            backoff = RESTART_BACKOFF_BASE;
        }
        log::error!(
            "{why} (restart #{restarts}); in-flight requests answered 503, \
             rebuilding the backend in {backoff:?}"
        );
        std::thread::sleep(backoff);
        backoff = (backoff * 2).min(RESTART_BACKOFF_CAP);
    }
}

/// Expire a dequeued request whose deadline already passed: release its
/// slot, answer 504, and keep it away from the backend.  Returns the
/// request back when it is still live.
fn expire_if_late(p: Pending, stats: &Mutex<BatchStats>) -> Option<Pending> {
    let Some(deadline) = p.deadline else {
        return Some(p); // no deadline configured: always live
    };
    let now = Instant::now();
    if now < deadline {
        return Some(p);
    }
    let waited_ms = now.duration_since(p.enqueued).as_millis() as u64;
    lock_stats(stats).timeouts += 1;
    p.slot.release();
    p.reply.send(Err(SubmitError::Timeout { waited_ms }));
    None
}

/// Why [`executor_loop`] returned control to [`supervise`].
enum ExecutorExit {
    /// Submit channel disconnected: every handle dropped, clean shutdown.
    Shutdown,
    /// The backend reported its memory poisoned
    /// ([`InferenceBackend::poisoned`]); rebuild it from the last good
    /// checkpoint.
    Poisoned,
}

/// The executor proper: collect a batch (max-batch-or-timeout), run the
/// backend, reply.  Panics unwind into [`supervise`]'s `catch_unwind`;
/// clean returns say why ([`ExecutorExit`]).
fn executor_loop(
    rx: &Receiver<Pending>,
    mut backend: Box<dyn InferenceBackend>,
    bpe: &Bpe,
    cfg: &BatcherConfig,
    stats: &Mutex<BatchStats>,
) -> ExecutorExit {
    let b_max = backend.max_batch();
    let seq_len = backend.seq_len();
    let vocab = backend.vocab();
    loop {
        // block for the first live request, then collect until full or
        // the oldest request exceeds max_wait
        let first = match rx.recv() {
            Ok(p) => p,
            Err(_) => return ExecutorExit::Shutdown, // all senders dropped
        };
        let Some(first) = expire_if_late(first, stats) else { continue };
        let mut group = vec![first];
        let deadline = group[0].enqueued + cfg.max_wait;
        while group.len() < b_max {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => {
                    if let Some(p) = expire_if_late(p, stats) {
                        group.push(p);
                    }
                }
                Err(_) => break,
            }
        }
        // chaos seam with requests in flight: `panic` exercises the
        // supervision boundary, `error` the failed-batch reply path
        if let Some(e) = failpoint::inject("batcher.exec") {
            fail_group(group, format!("{e:#}"), stats);
            continue;
        }
        let t0 = Instant::now();
        let fill = group.len();
        // ragged batch: exactly the filled rows, no padding — backends
        // own their shape requirements
        let mut tokens = Vec::with_capacity(fill * seq_len);
        for p in &group {
            tokens.extend(fit_length(p.tokens.clone(), seq_len));
        }
        let result = backend.infer(&tokens);
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        {
            let mut s = lock_stats(stats);
            s.requests += fill as u64;
            s.batches += 1;
            s.total_exec_latency_ms += exec_ms;
            s.max_batch_fill = s.max_batch_fill.max(fill);
            if let Some(m) = backend.memory_stats() {
                s.memory = Some(m);
            }
        }
        match result {
            Ok(logp) => {
                let mut latencies = Vec::with_capacity(fill);
                let mut truncated = 0u64;
                for (row, p) in group.into_iter().enumerate() {
                    let mut resp = extract_predictions(
                        &logp, row, seq_len, vocab, &p, bpe, cfg.top_k_cap, fill,
                    );
                    truncated += resp.masks.iter().filter(|m| m.is_truncated()).count() as u64;
                    // true request latency: enqueue → reply, so queueing
                    // and batch collection are included
                    let latency = p.enqueued.elapsed().as_secs_f64() * 1e3;
                    resp.latency_ms = latency;
                    latencies.push(latency);
                    // release the admission slot *before* the reply
                    // wakes the client: a client that pipelines its next
                    // request immediately must never be shed against its
                    // own slot
                    p.slot.release();
                    p.reply.send(Ok(resp));
                }
                let mut s = lock_stats(stats);
                for &l in &latencies {
                    s.total_request_latency_ms += l;
                    s.latency.record(l);
                }
                s.truncated_masks += truncated;
            }
            Err(e) => {
                let msg = format!("inference failed: {e:#}");
                if backend.poisoned() {
                    // the backend's mapped memory is known-corrupt (e.g.
                    // a contained SIGBUS): this batch gets a truthful 503
                    // (transient — the supervisor is about to rebuild
                    // from the last good checkpoint), and the executor
                    // hands the backend back instead of serving lies
                    fail_group_with(group, msg, stats, SubmitError::Unavailable);
                    return ExecutorExit::Poisoned;
                }
                fail_group(group, msg, stats)
            }
        }
    }
}

/// Answer every request of a failed batch with a 500-class error,
/// releasing slots and recording latencies (the failed requests still
/// count toward the latency mean).
fn fail_group(group: Vec<Pending>, msg: String, stats: &Mutex<BatchStats>) {
    fail_group_with(group, msg, stats, SubmitError::Internal)
}

/// [`fail_group`] with a caller-chosen error class (`Internal` → 500 for
/// batch failures, `Unavailable` → 503 when the backend is poisoned and
/// a rebuild is in flight).
fn fail_group_with(
    group: Vec<Pending>,
    msg: String,
    stats: &Mutex<BatchStats>,
    err: fn(String) -> SubmitError,
) {
    let mut latencies = Vec::with_capacity(group.len());
    for p in group {
        latencies.push(p.enqueued.elapsed().as_secs_f64() * 1e3);
        p.slot.release();
        p.reply.send(Err(err(msg.clone())));
    }
    let mut s = lock_stats(stats);
    for &l in &latencies {
        s.total_request_latency_ms += l;
        s.latency.record(l);
    }
}

/// `Retry-After` never suggests waiting longer than this, however deep
/// the queue — past a minute the client should be re-resolving, not
/// sleeping on one overloaded replica.
const MAX_RETRY_AFTER_SECS: u64 = 60;

/// The adaptive `Retry-After` estimate: the shed request would sit
/// behind `ceil(queue_depth / batch_capacity)` batches of roughly
/// `mean_batch_ms` each, so that is how long the client should wait
/// before trying again — floored at 1s (the HTTP-date-free minimum that
/// still means "back off") and capped at [`MAX_RETRY_AFTER_SECS`].
/// With no execution history yet the estimate degrades to the old
/// constant 1.
fn estimate_retry_after(queue_depth: usize, batch_capacity: usize, mean_batch_ms: f64) -> u64 {
    let batches_ahead = queue_depth.div_ceil(batch_capacity.max(1));
    let wait_secs = batches_ahead as f64 * mean_batch_ms.max(0.0) / 1e3;
    (wait_secs.ceil() as u64).clamp(1, MAX_RETRY_AFTER_SECS)
}

/// Tokenize text, mapping literal `[MASK]` spans to the mask id.
pub fn encode_with_masks(bpe: &Bpe, text: &str) -> (Vec<i32>, Vec<usize>) {
    let mut ids = vec![CLS_ID];
    let mut masks = Vec::new();
    let mut rest = text;
    while let Some(pos) = rest.find("[MASK]") {
        ids.extend(bpe.encode(&rest[..pos]));
        masks.push(ids.len());
        ids.push(MASK_ID);
        rest = &rest[pos + "[MASK]".len()..];
    }
    ids.extend(bpe.encode(rest));
    ids.push(SEP_ID);
    (ids, masks)
}

#[allow(clippy::too_many_arguments)]
fn extract_predictions(
    logp: &[f32],
    row: usize,
    seq_len: usize,
    vocab: usize,
    p: &Pending,
    bpe: &Bpe,
    top_k_cap: usize,
    batch_size: usize,
) -> PredictResponse {
    let mut masks = Vec::with_capacity(p.mask_positions.len());
    for &pos in &p.mask_positions {
        if pos >= seq_len {
            // the mask fell off the fixed-length batch row: surface an
            // explicit error, never a silent empty prediction
            masks.push(MaskPrediction::Truncated { position: pos, seq_len });
            continue;
        }
        let base = row * seq_len * vocab + pos * vocab;
        let scores = &logp[base..base + vocab];
        let k = p.top_k.min(top_k_cap);
        // partial top-k (shared with the lattice/PKM selection) instead
        // of sorting the entire vocab per mask position: O(V + k log k)
        masks.push(MaskPrediction::Scores(
            crate::util::topk::top_k_indices_f32(scores, k)
                .into_iter()
                .map(|i| TokenScore {
                    token: bpe.vocab.token(i as i32).to_string(),
                    logprob: scores[i] as f64,
                })
                .collect(),
        ));
    }
    PredictResponse { masks, latency_ms: 0.0, batch_size }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::BpeTrainer;

    fn bpe() -> Bpe {
        let mut t = BpeTrainer::new();
        t.add_text("the cat sat on the mat the cat sat");
        t.train(100)
    }

    fn test_stats() -> Mutex<BatchStats> {
        Mutex::new(rank::BATCH_STATS, BatchStats::default())
    }

    #[test]
    fn retry_after_grows_with_queue_depth_and_stays_bounded() {
        // the adaptive estimate behind the Retry-After header: deeper
        // queues must tell clients to back off longer
        let mean_ms = 80.0;
        let shallow = estimate_retry_after(8, 4, mean_ms);
        let mid = estimate_retry_after(128, 4, mean_ms);
        let deep = estimate_retry_after(2048, 4, mean_ms);
        assert!(shallow < mid && mid < deep, "{shallow} < {mid} < {deep} expected");
        // exact shape: ceil(depth/capacity) batches x mean seconds
        assert_eq!(mid, (128u64.div_ceil(4) as f64 * 0.08).ceil() as u64);
        // floors and caps: never 0 (it must still mean "back off"),
        // never past a minute, sane before any execution history exists
        assert_eq!(estimate_retry_after(0, 4, mean_ms), 1);
        assert_eq!(estimate_retry_after(100, 4, 0.0), 1);
        assert_eq!(estimate_retry_after(10_000_000, 4, mean_ms), MAX_RETRY_AFTER_SECS);
        // a zero capacity (backend not built yet) must not divide by zero
        assert_eq!(estimate_retry_after(16, 0, mean_ms), 2);
    }

    #[test]
    fn encode_with_masks_finds_positions() {
        let b = bpe();
        let (ids, masks) = encode_with_masks(&b, "the [MASK] sat on the [MASK]");
        assert_eq!(masks.len(), 2);
        for &m in &masks {
            assert_eq!(ids[m], MASK_ID);
        }
        assert_eq!(ids[0], CLS_ID);
        assert_eq!(*ids.last().unwrap(), SEP_ID);
    }

    #[test]
    fn no_mask_text_has_no_positions() {
        let b = bpe();
        let (_, masks) = encode_with_masks(&b, "the cat sat");
        assert!(masks.is_empty());
    }

    #[test]
    fn slot_guard_releases_exactly_once_from_both_sides() {
        // the double-release hazard: after an executor panic, both the
        // executor's reply path and the client's error path reach for
        // the same admission slot
        let pending = Arc::new(AtomicUsize::new(3));
        let slot = Arc::new(SlotGuard { pending: pending.clone(), released: AtomicBool::new(false) });
        let other = slot.clone();
        slot.release();
        other.release();
        slot.release();
        assert_eq!(pending.load(Ordering::Relaxed), 2, "exactly one decrement");
    }

    #[test]
    fn health_state_machine_and_draining_is_terminal() {
        let h = Health::default();
        assert_eq!(h.state(), HealthState::Starting);
        assert_eq!(h.restarts(), 0);
        h.transition(HealthState::Ready);
        assert_eq!(h.state(), HealthState::Ready);
        h.transition(HealthState::Degraded);
        assert_eq!(h.note_restart(), 1);
        assert_eq!(h.restarts(), 1);
        h.set_draining();
        // supervisor transitions must not resurrect a draining server
        h.transition(HealthState::Ready);
        assert_eq!(h.state(), HealthState::Draining);
        assert_eq!(HealthState::from_u8(HealthState::Degraded as u8), HealthState::Degraded);
        for s in
            [HealthState::Starting, HealthState::Ready, HealthState::Degraded, HealthState::Draining]
        {
            assert!(!s.as_str().is_empty());
        }
    }

    #[test]
    fn expired_request_gets_504_and_frees_its_slot_without_backend_contact() {
        let stats = test_stats();
        let pending = Arc::new(AtomicUsize::new(1));
        let (reply, rx) = channel();
        let now = Instant::now();
        let enqueued = now.checked_sub(Duration::from_millis(50)).unwrap_or(now);
        let p = Pending {
            tokens: vec![CLS_ID, MASK_ID, SEP_ID],
            mask_positions: vec![1],
            top_k: 1,
            reply: ReplyTo { tx: reply, notify: None },
            enqueued,
            deadline: Some(now), // already in the past once checked
            slot: Arc::new(SlotGuard { pending: pending.clone(), released: AtomicBool::new(false) }),
        };
        assert!(expire_if_late(p, &stats).is_none(), "expired request must not survive");
        assert_eq!(pending.load(Ordering::Relaxed), 0, "slot must be freed");
        assert_eq!(lock_stats(&stats).timeouts, 1);
        match rx.recv().unwrap() {
            Err(SubmitError::Timeout { .. }) => {}
            other => panic!("expected Timeout, got {other:?}"),
        }
    }

    #[test]
    fn live_request_passes_deadline_check_untouched() {
        let stats = test_stats();
        let (reply, _rx) = channel();
        let now = Instant::now();
        let p = Pending {
            tokens: vec![CLS_ID, MASK_ID, SEP_ID],
            mask_positions: vec![1],
            top_k: 1,
            reply: ReplyTo { tx: reply, notify: None },
            enqueued: now,
            deadline: Some(now + Duration::from_secs(3600)),
            slot: test_slot(),
        };
        let back = expire_if_late(p, &stats).expect("live request must pass through");
        assert_eq!(back.mask_positions, vec![1]);
        assert_eq!(lock_stats(&stats).timeouts, 0);
        // and a deadline-less request is always live
        let (reply, _rx2) = channel();
        let p = Pending {
            tokens: vec![CLS_ID, MASK_ID, SEP_ID],
            mask_positions: vec![1],
            top_k: 1,
            reply: ReplyTo { tx: reply, notify: None },
            // checked_sub: a fresh VM's Instant epoch may be younger
            // than the offset, and bare subtraction would panic
            enqueued: now.checked_sub(Duration::from_secs(9999)).unwrap_or(now),
            deadline: None,
            slot: test_slot(),
        };
        assert!(expire_if_late(p, &stats).is_some());
    }

    fn test_slot() -> Arc<SlotGuard> {
        Arc::new(SlotGuard {
            pending: Arc::new(AtomicUsize::new(1)),
            released: AtomicBool::new(false),
        })
    }

    #[test]
    fn reply_to_fires_its_notifier_exactly_once_on_send_and_on_drop() {
        // send path: the notifier fires once, after the outcome became
        // readable (the waiter's try_take must succeed when woken)
        let fired = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        let n = fired.clone();
        let r = ReplyTo {
            tx,
            notify: Some(Arc::new(move || {
                n.fetch_add(1, Ordering::SeqCst);
            })),
        };
        r.send(Err(SubmitError::Internal("boom".into())));
        assert_eq!(fired.load(Ordering::SeqCst), 1, "send fires the notifier exactly once");
        assert!(rx.try_recv().is_ok(), "the outcome was readable by notify time");

        // drop-without-send path (executor unwind mid-batch): the
        // notifier still fires so no event-loop waiter sleeps forever
        let dropped = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel::<Result<PredictResponse, SubmitError>>();
        let n = dropped.clone();
        drop(ReplyTo {
            tx,
            notify: Some(Arc::new(move || {
                n.fetch_add(1, Ordering::SeqCst);
            })),
        });
        assert_eq!(dropped.load(Ordering::SeqCst), 1, "drop fires the notifier exactly once");
        assert!(rx.try_recv().is_err(), "no outcome: the waiter sees the disconnect");
    }

    #[test]
    fn pending_reply_surfaces_executor_death_and_frees_the_slot() {
        let pending = Arc::new(AtomicUsize::new(1));
        let slot =
            Arc::new(SlotGuard { pending: pending.clone(), released: AtomicBool::new(false) });
        let (tx, rx) = channel();
        let pr = PendingReply { rx, slot };
        assert!(pr.try_take().is_none(), "in flight: no outcome yet, slot stays claimed");
        assert_eq!(pending.load(Ordering::Relaxed), 1);
        drop(tx); // the executor unwound without replying
        match pr.try_take() {
            Some(Err(SubmitError::Unavailable(m))) => {
                assert!(m.contains("executor failed"), "honest transient wording: {m}")
            }
            _ => panic!("expected Unavailable after executor death"),
        }
        assert_eq!(pending.load(Ordering::Relaxed), 0, "slot reclaimed on the error path");
    }

    #[test]
    fn truncated_mask_position_becomes_explicit_error() {
        let b = bpe();
        let (reply, _rx) = channel();
        let p = Pending {
            tokens: vec![CLS_ID, 5, MASK_ID, SEP_ID],
            mask_positions: vec![2, 9], // 9 is beyond seq_len 4
            top_k: 2,
            reply: ReplyTo { tx: reply, notify: None },
            enqueued: Instant::now(),
            deadline: None,
            slot: test_slot(),
        };
        let vocab = b.vocab_size();
        let logp = vec![-1.0f32; 4 * vocab];
        let resp = extract_predictions(&logp, 0, 4, vocab, &p, &b, 5, 1);
        assert_eq!(resp.masks.len(), 2);
        assert!(resp.masks[0].scores().is_some());
        match resp.masks[1] {
            MaskPrediction::Truncated { position, seq_len } => {
                assert_eq!(position, 9);
                assert_eq!(seq_len, 4);
            }
            _ => panic!("expected truncation error"),
        }
    }
}
