//! MLM serving: a vLLM-router-style coordinator — an event-driven
//! keep-alive HTTP front door (`poll(2)` loops multiplexing nonblocking
//! connections) with bounded admission and load shedding, dynamic
//! batcher, pluggable inference backend — with python nowhere on the
//! path.  See `docs/serving.md` for the operator view.
//!
//! Requests (`POST /v1/predict` with `{"text": "... [MASK] ..."}`;
//! `/predict` is a compatibility alias) are
//! tokenized, queued, and coalesced by the [`batcher`] into (possibly
//! ragged) batches for an [`InferenceBackend`]; responses carry the
//! top-k predictions for every `[MASK]` position.  Two backends exist:
//! the AOT PJRT artifact executor ([`ArtifactBackend`]) and the
//! artifact-free pure-rust lattice engine ([`EngineBackend`]), which
//! serves the paper's O(1)-lookup path on any machine.

pub mod api;
pub mod backend;
pub mod batcher;
mod http;

pub use api::{MaskPrediction, PredictRequest, PredictResponse, TokenScore};
pub use backend::{
    resolve_checkpoint_flag, ArtifactBackend, ArtifactInit, BackendInit, BackendStats,
    CheckpointInit, EngineBackend, EngineConfig, InferenceBackend, NumericPath, ShardStats,
};
pub use batcher::{Batcher, BatcherConfig, Health, HealthState, SubmitError};
pub use http::{
    serve, serve_until_signaled, serve_with, HttpConfig, HttpStats, Server, ShutdownHandle,
};
