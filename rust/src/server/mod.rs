//! MLM serving: a vLLM-router-style coordinator — TCP front door,
//! dynamic batcher, PJRT executor — with python nowhere on the path.
//!
//! Requests (`POST /predict` with `{"text": "... [MASK] ..."}`) are
//! tokenized, queued, and coalesced by the [`batcher`] into fixed-shape
//! batches for the `infer_logits_<variant>` artifact; responses carry the
//! top-k predictions for every `[MASK]` position.

pub mod api;
pub mod batcher;
mod http;

pub use api::{PredictRequest, PredictResponse, TokenScore};
pub use batcher::{Batcher, BatcherConfig, BatcherInit};
pub use http::serve;
