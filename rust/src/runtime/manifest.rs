//! Artifact manifests: the JSON contract between `aot.py` and the runtime.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Element type tag used throughout the manifest files.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
    F64,
    I64,
}

impl Dtype {
    pub fn parse(tag: &str) -> Result<Self> {
        Ok(match tag {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u32" => Dtype::U32,
            "f64" => Dtype::F64,
            "i64" => Dtype::I64,
            other => bail!("unknown dtype tag '{other}'"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 | Dtype::U32 => 4,
            Dtype::F64 | Dtype::I64 => 8,
        }
    }
}

/// Shape + dtype (+ optional name) of one positional tensor.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: v.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            shape: v.req("shape")?.as_usize_vec()?,
            dtype: Dtype::parse(
                v.req("dtype")?.as_str().ok_or_else(|| anyhow!("dtype not a string"))?,
            )?,
        })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.element_count() * self.dtype.size_bytes()
    }
}

/// Batch geometry recorded by the exporter (when applicable).
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchSpec {
    pub b: usize,
    pub s: usize,
}

/// `<name>.meta.json`, written by `python/compile/aot.py` for every HLO
/// artifact.  Positional calling convention: `state ++ inputs`; the first
/// `n_state_outputs` outputs are the updated state.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifact: String,
    pub state: Vec<TensorSpec>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub n_state_outputs: usize,
    pub kind: String,
    pub variant: String,
    pub batch: BatchSpec,
    pub n_params: Option<u64>,
    pub width: Option<usize>,
    pub locations: Option<u64>,
    pub heads: Option<usize>,
    pub k_top: Option<usize>,
    pub m: Option<usize>,
    pub n_keys: Option<usize>,
    pub access_outputs: bool,
    pub dir: PathBuf,
    pub name: String,
}

impl Manifest {
    pub fn load(dir: &Path, name: &str) -> Result<Self> {
        let path = dir.join(format!("{name}.meta.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow!("{key} not an array"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        let batch = match v.get("batch") {
            Some(b) => BatchSpec {
                b: b.get("B").and_then(Json::as_usize).unwrap_or(0),
                s: b.get("S").and_then(Json::as_usize).unwrap_or(0),
            },
            None => BatchSpec::default(),
        };
        let opt_usize = |key: &str| v.get(key).and_then(Json::as_usize);
        let m = Manifest {
            artifact: v
                .req("artifact")?
                .as_str()
                .ok_or_else(|| anyhow!("artifact not a string"))?
                .to_string(),
            state: specs("state")?,
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            n_state_outputs: v
                .req("n_state_outputs")?
                .as_usize()
                .ok_or_else(|| anyhow!("n_state_outputs not an int"))?,
            kind: v.get("kind").and_then(Json::as_str).unwrap_or("").to_string(),
            variant: v.get("variant").and_then(Json::as_str).unwrap_or("").to_string(),
            batch,
            n_params: v.get("n_params").and_then(Json::as_i64).map(|x| x as u64),
            width: opt_usize("width"),
            locations: v.get("locations").and_then(Json::as_i64).map(|x| x as u64),
            heads: opt_usize("heads"),
            k_top: opt_usize("k_top"),
            m: opt_usize("m"),
            n_keys: opt_usize("n_keys"),
            access_outputs: v.get("access_outputs").and_then(Json::as_bool).unwrap_or(false),
            dir: dir.to_path_buf(),
            name: name.to_string(),
        };
        if m.n_state_outputs > m.outputs.len() {
            bail!("manifest {name}: n_state_outputs exceeds output count");
        }
        Ok(m)
    }

    pub fn hlo_path(&self) -> PathBuf {
        self.dir.join(&self.artifact)
    }

    /// Path of the initial-state binary for this artifact's variant.
    pub fn state_bin_path(&self) -> PathBuf {
        self.dir.join(format!("{}.state.bin", self.variant))
    }

    pub fn result_specs(&self) -> &[TensorSpec] {
        &self.outputs[self.n_state_outputs..]
    }

    pub fn total_state_bytes(&self) -> usize {
        self.state.iter().map(|s| s.byte_len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("lram_man_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("toy.meta.json"),
            r#"{"artifact": "toy.hlo.txt",
                "state": [{"name": "p/w", "shape": [2, 3], "dtype": "f32"}],
                "inputs": [{"name": "x", "shape": [4], "dtype": "i32"}],
                "outputs": [{"shape": [2, 3], "dtype": "f32"}, {"shape": [], "dtype": "f32"}],
                "n_state_outputs": 1, "kind": "test", "variant": "toy",
                "batch": {"B": 4, "S": 1}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir, "toy").unwrap();
        assert_eq!(m.state[0].byte_len(), 24);
        assert_eq!(m.inputs[0].dtype, Dtype::I32);
        assert_eq!(m.result_specs().len(), 1);
        assert_eq!(m.batch.b, 4);
        assert_eq!(m.total_state_bytes(), 24);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_state_count() {
        let dir = std::env::temp_dir().join(format!("lram_man2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("bad.meta.json"),
            r#"{"artifact": "b.hlo.txt", "state": [], "inputs": [],
                "outputs": [], "n_state_outputs": 3}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir, "bad").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
