//! Host-side tensor helpers: build xla Literals from raw data and read
//! results back without guessing dtypes.

use anyhow::{bail, Result};
use xla::{ArrayElement, ElementType, Literal};

use super::manifest::{Dtype, TensorSpec};

/// A tensor on the host, mirroring the manifest dtypes we actually use.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v, _) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            HostTensor::F32(v, s) => literal_f32(v, s),
            HostTensor::I32(v, s) => literal_i32(v, s),
        }
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => Ok(HostTensor::F32(lit.to_vec::<f32>()?, dims)),
            ElementType::S32 => Ok(HostTensor::I32(lit.to_vec::<i32>()?, dims)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

/// Build a rank-N f32 literal from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal_f32: {} elements for shape {:?}", data.len(), shape);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Build a rank-N i32 literal from a flat slice.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal_i32: {} elements for shape {:?}", data.len(), shape);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Build a literal of the spec's dtype from raw little-endian bytes
/// (the `*.state.bin` format written by `aot.py`).
pub fn literal_from_bytes(spec: &TensorSpec, bytes: &[u8]) -> Result<Literal> {
    if bytes.len() != spec.byte_len() {
        bail!(
            "state tensor {}: expected {} bytes, got {}",
            spec.name,
            spec.byte_len(),
            bytes.len()
        );
    }
    match spec.dtype {
        Dtype::F32 => {
            let v: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            literal_f32(&v, &spec.shape)
        }
        Dtype::I32 => {
            let v: Vec<i32> = bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            literal_i32(&v, &spec.shape)
        }
        other => bail!("state dtype {other:?} not supported"),
    }
}

/// Check a literal matches its manifest spec (debug aid for artifact drift).
pub fn check_spec(lit: &Literal, spec: &TensorSpec) -> Result<()> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    if dims != spec.shape {
        bail!("tensor {}: shape {:?} != manifest {:?}", spec.name, dims, spec.shape);
    }
    let ok = matches!(
        (shape.ty(), spec.dtype),
        (ElementType::F32, Dtype::F32) | (ElementType::S32, Dtype::I32)
    );
    if !ok {
        bail!("tensor {}: dtype mismatch vs manifest {:?}", spec.name, spec.dtype);
    }
    Ok(())
}

/// Convenience: total f32 element count sanity check used by tests.
#[allow(dead_code)]
pub fn element_count<T: ArrayElement>(lit: &Literal) -> usize {
    lit.element_count()
}
