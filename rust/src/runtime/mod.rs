//! PJRT runtime: load AOT artifacts (HLO text + manifest) and execute them.
//!
//! The python compile path (`python/compile/aot.py`) lowers every jitted
//! computation to HLO *text* — the only interchange format the bundled
//! xla_extension 0.5.1 accepts from jax >= 0.5 — alongside a
//! `<name>.meta.json` manifest describing the positional `state` and
//! `input` tensors and the output layout.  This module wraps the `xla`
//! crate (`PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//! `compile` -> `execute`) behind an [`Artifact`] handle that keeps model
//! state device-side between calls.

mod artifact;
mod literal;
mod manifest;

pub use artifact::{Artifact, ArtifactState, Runtime};
pub use literal::{literal_f32, literal_i32, HostTensor};
pub use manifest::{Dtype, Manifest, TensorSpec};
