//! Compiled-artifact handles: one PJRT executable per AOT'd computation,
//! with manifest-driven positional marshalling of state and inputs.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::Literal;

use super::literal::{literal_from_bytes, HostTensor};
use super::manifest::Manifest;

/// Shared PJRT CPU client + artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    compiled: std::sync::Mutex<HashMap<String, Arc<Artifact>>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime rooted at an artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client ready: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Self {
            client,
            dir: artifact_dir.as_ref().to_path_buf(),
            compiled: std::sync::Mutex::new(HashMap::new()),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&self, name: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self.compiled.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let manifest = Manifest::load(&self.dir, name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(manifest.hlo_path())
            .with_context(|| format!("parsing HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        log::info!("compiled artifact {name} in {:.2}s", t0.elapsed().as_secs_f32());
        let a = Arc::new(Artifact { manifest, exe, client: self.client.clone() });
        self.compiled.lock().unwrap().insert(name.to_string(), a.clone());
        Ok(a)
    }

    /// Names of every artifact manifest present in the directory.
    pub fn available(&self) -> Result<Vec<String>> {
        let mut names = vec![];
        for entry in std::fs::read_dir(&self.dir)? {
            let p = entry?.path();
            if let Some(f) = p.file_name().and_then(|f| f.to_str()) {
                if let Some(stem) = f.strip_suffix(".meta.json") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }
}

/// A compiled computation plus its manifest.
pub struct Artifact {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

/// Mutable model state (params, optimizer moments, BN stats) held as host
/// literals between calls, positionally matching `manifest.state`.
pub struct ArtifactState {
    pub tensors: Vec<Literal>,
}

impl Artifact {
    /// Load the variant's initial state from `<variant>.state.bin`.
    pub fn initial_state(&self) -> Result<ArtifactState> {
        let path = self.manifest.state_bin_path();
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading initial state {}", path.display()))?;
        if bytes.len() != self.manifest.total_state_bytes() {
            bail!(
                "state bin {}: {} bytes, manifest expects {}",
                path.display(),
                bytes.len(),
                self.manifest.total_state_bytes()
            );
        }
        let mut tensors = Vec::with_capacity(self.manifest.state.len());
        let mut off = 0usize;
        for spec in &self.manifest.state {
            let n = spec.byte_len();
            tensors.push(literal_from_bytes(spec, &bytes[off..off + n])?);
            off += n;
        }
        Ok(ArtifactState { tensors })
    }

    /// Zero-filled state matching the manifest (micro-bench artifacts
    /// ship no `.state.bin`; their weights only matter for timing).
    pub fn zero_state(&self) -> Result<ArtifactState> {
        let mut tensors = Vec::with_capacity(self.manifest.state.len());
        for spec in &self.manifest.state {
            let n = spec.element_count();
            let lit = match spec.dtype {
                super::manifest::Dtype::F32 => {
                    super::literal::literal_f32(&vec![0.0f32; n], &spec.shape)?
                }
                super::manifest::Dtype::I32 => {
                    super::literal::literal_i32(&vec![0i32; n], &spec.shape)?
                }
                other => bail!("zero_state: dtype {other:?} unsupported"),
            };
            tensors.push(lit);
        }
        Ok(ArtifactState { tensors })
    }

    /// `initial_state` if the variant ships a `.state.bin`, else zeros.
    pub fn initial_state_or_zeros(&self) -> Result<ArtifactState> {
        if self.manifest.state_bin_path().exists() {
            self.initial_state()
        } else {
            self.zero_state()
        }
    }

    /// Execute with `state ++ inputs`; splits the result into
    /// (new_state, results) per the manifest, updating `state` in place.
    pub fn step(&self, state: &mut ArtifactState, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if state.tensors.len() != self.manifest.state.len() {
            bail!(
                "artifact {}: state has {} tensors, manifest expects {}",
                self.manifest.name,
                state.tensors.len(),
                self.manifest.state.len()
            );
        }
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "artifact {}: got {} inputs, manifest expects {}",
                self.manifest.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        // NOTE: go through execute_b with buffers we own — the C shim
        // behind `execute(<literals>)` leaks its internally-created input
        // buffers (one full state copy per step; discovered when the
        // 241 MB-state lram_large variant OOM'd at ~step 120).  Buffers
        // created here are freed by PjRtBuffer::drop.
        let mut args: Vec<Literal> = Vec::with_capacity(state.tensors.len() + inputs.len());
        args.append(&mut state.tensors);
        for t in inputs {
            args.push(t.to_literal()?);
        }
        let mut bufs = Vec::with_capacity(args.len());
        for lit in &args {
            bufs.push(self.client.buffer_from_host_literal(None, lit)?);
        }
        let result = self.exe.execute_b(&bufs)?;
        // PJRT execution is asynchronous: the input buffers (and their
        // source literals) must stay alive until the output is
        // materialised by to_literal_sync below.
        let root = result[0][0].to_literal_sync()?;
        drop(bufs);
        drop(args);
        let mut outs = root.to_tuple()?;
        if outs.len() != self.manifest.outputs.len() {
            bail!(
                "artifact {}: {} outputs, manifest expects {}",
                self.manifest.name,
                outs.len(),
                self.manifest.outputs.len()
            );
        }
        let results = outs.split_off(self.manifest.n_state_outputs);
        state.tensors = outs;
        results.iter().map(|l| HostTensor::from_literal(l)).collect()
    }

    /// Execute a stateless (read-only state) call: state is restored
    /// afterwards even though the artifact returns it.
    pub fn call(&self, state: &mut ArtifactState, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.step(state, inputs)
    }
}

impl ArtifactState {
    /// Serialize to the same flat binary layout as `aot.py` (checkpoints).
    pub fn to_bytes(&self, manifest: &Manifest) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(manifest.total_state_bytes());
        for (lit, spec) in self.tensors.iter().zip(&manifest.state) {
            super::literal::check_spec(lit, spec)?;
            match spec.dtype {
                super::manifest::Dtype::F32 => {
                    for v in lit.to_vec::<f32>()? {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
                super::manifest::Dtype::I32 => {
                    for v in lit.to_vec::<i32>()? {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
                other => bail!("checkpoint dtype {other:?} unsupported"),
            }
        }
        Ok(out)
    }

    /// Restore from checkpoint bytes.
    pub fn from_bytes(manifest: &Manifest, bytes: &[u8]) -> Result<Self> {
        if bytes.len() != manifest.total_state_bytes() {
            bail!("checkpoint size mismatch");
        }
        let mut tensors = Vec::with_capacity(manifest.state.len());
        let mut off = 0;
        for spec in &manifest.state {
            let n = spec.byte_len();
            tensors.push(literal_from_bytes(spec, &bytes[off..off + n])?);
            off += n;
        }
        Ok(Self { tensors })
    }
}
