//! The differentiable-routing contract, locked in by finite differences.
//!
//! The paper's premise is a *differentiable* random access memory: the
//! kernel weights `w_j = f(d2_j)` are a smooth function of the query,
//! so the loss can flow through the lattice lookup into the query
//! projection `wq`.  This harness verifies every gradient the pure-rust
//! [`EngineTrainer`] computes — `wq` (the new routing path), the
//! embeddings (which see *both* the residual and the routing path),
//! `wo`, `w_out`, and the touched value-table rows — against central
//! finite differences of an **f64 reference forward** implemented here
//! from the same weights (scalar [`LatticeLookup`] oracle driving the
//! memory stage, everything else upcast to f64).
//!
//! Checking an f32-computed analytic gradient against an f64 numeric
//! one is the point: the f64 forward has a ~1e-11 finite-difference
//! noise floor, so the comparison isolates the *derivation* (is the
//! math right?) from f32 rounding, and the contract `rtol = 1e-3`
//! (`util::check::assert_grad_close`) has real teeth.
//!
//! The gradient-check model selects **all 232 candidates**
//! (`k_top = 232`), so no top-k truncation happens and the loss is a
//! smooth function of every parameter — the regime where central
//! differences converge.  Training-shaped configs (k_top = 32) drop
//! only near-zero-weight hits, whose derivative contribution vanishes
//! at the support boundary (see `lattice::kernel` boundary tests).
//!
//! Also here: the convergence gate — trained routing must reach
//! strictly lower eval loss than frozen routing on the synthetic MLM
//! task — because a gradient can be correct and still useless.

use lram::coordinator::{EngineTrainConfig, EngineTrainer};
use lram::data::Batch;
use lram::lattice::{BackwardCache, BatchLookupEngine, BatchOutput, LatticeLookup, TorusK};
use lram::memstore::ValueTable;
use lram::model::EngineConfig;
use lram::util::check::assert_grad_close;
use lram::util::rng::Rng;

/// Every in-support candidate selected: the loss is smooth in the
/// queries, so finite differences see exactly what the backward computes.
const K_ALL: usize = 232;

const RTOL: f64 = 1e-3;
const ATOL: f64 = 1e-6;
/// Central-difference step: weights are O(1) and the reference forward
/// is f64, so truncation (~h^2) and cancellation (~1e-16/h) both stay
/// far below the f32-analytic tolerance.
const FD_H: f64 = 1e-4;

fn grad_cfg() -> EngineTrainConfig {
    EngineTrainConfig {
        model: EngineConfig {
            max_batch: 2,
            seq_len: 8,
            width: 8,
            heads: 2,
            m: 4,
            k_top: K_ALL,
            torus_k: [4; 8], // 256 slots: tiny, same structure
            threads: 1,
            ..EngineConfig::default()
        },
        steps: 4,
        batch: 2,
        vocab_size: 128,
        mask_prob: 0.3,
        ..EngineTrainConfig::default()
    }
}

// ---------------------------------------------------------------------
// the f64 reference forward
// ---------------------------------------------------------------------

/// All trainable tensors of the engine model, upcast to f64, plus the
/// geometry needed to rerun the forward pass: the numeric-gradient
/// oracle.  Same function as `LramMlm::forward` + the trainer's masked
/// cross-entropy, different precision.
struct RefModel {
    vocab: usize,
    width: usize,
    heads: usize,
    m: usize,
    k_top: usize,
    query_scale: f64,
    torus: TorusK,
    embed: Vec<f64>,
    pos: Vec<f64>,
    wq: Vec<f64>,
    wo: Vec<f64>,
    w_out: Vec<f64>,
    values: Vec<f64>,
}

impl RefModel {
    fn from_trainer(t: &EngineTrainer) -> RefModel {
        let m = &t.model;
        let cfg = &m.cfg;
        let up = |v: &[f32]| -> Vec<f64> { v.iter().map(|&x| x as f64).collect() };
        RefModel {
            vocab: m.vocab,
            width: cfg.width,
            heads: cfg.heads,
            m: cfg.m,
            k_top: cfg.k_top,
            query_scale: cfg.query_scale,
            torus: TorusK::new(cfg.torus_k).unwrap(),
            embed: up(&m.embed),
            pos: up(&m.pos),
            wq: up(&m.wq),
            wo: up(&m.wo),
            w_out: up(&m.w_out),
            values: up(m.table.data()),
        }
    }

    fn clamp(&self, t: i32) -> usize {
        if t < 0 || t as usize >= self.vocab {
            (lram::tokenizer::UNK_ID as usize).min(self.vocab - 1)
        } else {
            t as usize
        }
    }

    /// Masked cross-entropy of `batch`, entirely in f64 (scalar lattice
    /// oracle for the memory stage — `LatticeLookup` is f64 end to end).
    fn loss(&self, batch: &Batch) -> f64 {
        let (s, wd, heads, m) = (batch.s, self.width, self.heads, self.m);
        let mut lk = LatticeLookup::new(self.torus, self.k_top);
        let total_w: f64 = batch.weights.iter().map(|&w| w as f64).sum();
        assert!(total_w > 0.0, "gradcheck batch must contain masked positions");
        let mut loss = 0.0f64;
        let mut h = vec![0.0f64; wd];
        let mut v = vec![0.0f64; heads * m];
        let mut logits = vec![0.0f64; self.vocab];
        for p in 0..batch.b * batch.s {
            let w_p = batch.weights[p] as f64;
            if w_p == 0.0 {
                continue; // unmasked positions carry no loss
            }
            let c = p % s;
            // h = embed[t] + pos[c] + 0.5 embed[left] + 0.5 embed[right]
            let t = self.clamp(batch.tokens[p]);
            for w in 0..wd {
                h[w] = self.embed[t * wd + w] + self.pos[c * wd + w];
            }
            if c > 0 {
                let lt = self.clamp(batch.tokens[p - 1]);
                for w in 0..wd {
                    h[w] += 0.5 * self.embed[lt * wd + w];
                }
            }
            if c + 1 < s {
                let rt = self.clamp(batch.tokens[p + 1]);
                for w in 0..wd {
                    h[w] += 0.5 * self.embed[rt * wd + w];
                }
            }
            // memory stage: q = scale * wq h → lattice → v = Σ w_j T[idx_j]
            for head in 0..heads {
                let vh = &mut v[head * m..(head + 1) * m];
                vh.fill(0.0);
                let mut q = [0.0f64; 8];
                for (d, qd) in q.iter_mut().enumerate() {
                    let row = &self.wq[(head * 8 + d) * wd..(head * 8 + d + 1) * wd];
                    *qd = row.iter().zip(&h).map(|(a, b)| a * b).sum::<f64>()
                        * self.query_scale;
                }
                let r = lk.lookup(&q);
                for hit in &r.hits {
                    let row =
                        &self.values[hit.index as usize * m..(hit.index as usize + 1) * m];
                    for (o, val) in vh.iter_mut().zip(row) {
                        *o += hit.weight * val;
                    }
                }
            }
            // y = h + wo v; logits = w_out y; masked CE via log-softmax
            for (ti, logit) in logits.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for w in 0..wd {
                    let mut y = h[w];
                    let wo_row = &self.wo[w * (heads * m)..(w + 1) * (heads * m)];
                    for (j, &vj) in v.iter().enumerate() {
                        y += wo_row[j] * vj;
                    }
                    acc += self.w_out[ti * wd + w] * y;
                }
                *logit = acc;
            }
            let maxv = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lse = maxv + logits.iter().map(|l| (l - maxv).exp()).sum::<f64>().ln();
            let target = batch.targets[p] as usize;
            loss -= (logits[target] - lse) * w_p / total_w;
        }
        loss
    }
}

/// Which f64 tensor a parameter coordinate lives in.
#[derive(Clone, Copy)]
enum Tensor {
    Embed,
    Pos,
    Wq,
    Wo,
    WOut,
    Values,
}

fn tensor_mut<'a>(model: &'a mut RefModel, t: Tensor) -> &'a mut Vec<f64> {
    match t {
        Tensor::Embed => &mut model.embed,
        Tensor::Pos => &mut model.pos,
        Tensor::Wq => &mut model.wq,
        Tensor::Wo => &mut model.wo,
        Tensor::WOut => &mut model.w_out,
        Tensor::Values => &mut model.values,
    }
}

/// Central finite difference of the reference loss w.r.t. one coordinate.
fn numeric_grad(model: &mut RefModel, batch: &Batch, t: Tensor, idx: usize) -> f64 {
    let original = tensor_mut(model, t)[idx];
    tensor_mut(model, t)[idx] = original + FD_H;
    let up = model.loss(batch);
    tensor_mut(model, t)[idx] = original - FD_H;
    let down = model.loss(batch);
    tensor_mut(model, t)[idx] = original;
    (up - down) / (2.0 * FD_H)
}

/// Check `analytic` against numeric gradients on a subset of
/// coordinates: every nonzero-gradient coordinate (thinned to `cap`)
/// plus the first few zero-gradient ones (which must check out as ~0
/// numerically too — a zero that should not be zero is the classic
/// missing-term bug).
fn check_tensor(
    name: &str,
    model: &mut RefModel,
    batch: &Batch,
    t: Tensor,
    analytic: &[f32],
    cap: usize,
) {
    assert_eq!(analytic.len(), tensor_mut(model, t).len(), "{name}: shape mismatch");
    let nonzero: Vec<usize> =
        (0..analytic.len()).filter(|&i| analytic[i] != 0.0).collect();
    assert!(!nonzero.is_empty(), "{name}: no gradient flowed at all");
    let stride = (nonzero.len() / cap).max(1);
    let mut indices: Vec<usize> = nonzero.iter().step_by(stride).cloned().collect();
    indices.extend((0..analytic.len()).filter(|&i| analytic[i] == 0.0).take(3));
    let mut a = Vec::with_capacity(indices.len());
    let mut n = Vec::with_capacity(indices.len());
    for &i in &indices {
        a.push(analytic[i] as f64);
        n.push(numeric_grad(model, batch, t, i));
    }
    assert_grad_close(name, &a, &n, RTOL, ATOL);
}

// ---------------------------------------------------------------------
// the gradient checks
// ---------------------------------------------------------------------

/// A trainer a few steps in (so weights are off their symmetric init),
/// the batch it will see next, and its filled gradient buffers.
fn trained_trainer_with_grads() -> (EngineTrainer, Batch) {
    let mut t = EngineTrainer::new(grad_cfg()).unwrap();
    for _ in 0..2 {
        t.train_step().unwrap();
    }
    let batch = t.pipeline().train_batch(t.step_count());
    let total: f32 = batch.weights.iter().sum();
    assert!(total > 0.0, "gradcheck batch has no masked positions");
    t.forward_backward(&batch).unwrap();
    (t, batch)
}

#[test]
fn f64_reference_forward_matches_the_f32_training_loss() {
    // anchor: before trusting the reference as a numeric-gradient
    // oracle, it must agree with the f32 forward on the loss itself
    let (mut t, batch) = trained_trainer_with_grads();
    let loss32 = t.forward_backward(&batch).unwrap();
    let reference = RefModel::from_trainer(&t);
    let loss64 = reference.loss(&batch);
    assert!(
        (loss64 - loss32).abs() <= 1e-4 * (1.0 + loss32.abs()),
        "f64 reference {loss64} diverges from f32 forward {loss32}"
    );
}

#[test]
fn wq_gradient_matches_finite_differences() {
    // the tentpole: d(loss)/d(wq) through the lattice kernel — every
    // coordinate of the routing projection, not a sample
    let (t, batch) = trained_trainer_with_grads();
    let mut reference = RefModel::from_trainer(&t);
    let wq = t.grads().wq.to_vec();
    check_tensor("wq", &mut reference, &batch, Tensor::Wq, &wq, usize::MAX);
}

#[test]
fn embedding_gradients_match_finite_differences() {
    // embeddings see the residual path AND the routing path (via h →
    // q); a missing routing term fails here, not just on wq
    let (t, batch) = trained_trainer_with_grads();
    let mut reference = RefModel::from_trainer(&t);
    let embed = t.grads().embed.to_vec();
    check_tensor("embed", &mut reference, &batch, Tensor::Embed, &embed, 48);
    let pos = t.grads().pos.to_vec();
    check_tensor("pos", &mut reference, &batch, Tensor::Pos, &pos, 48);
}

#[test]
fn dense_suffix_gradients_match_finite_differences() {
    let (t, batch) = trained_trainer_with_grads();
    let mut reference = RefModel::from_trainer(&t);
    let wo = t.grads().wo.to_vec();
    check_tensor("wo", &mut reference, &batch, Tensor::Wo, &wo, usize::MAX);
    let w_out = t.grads().w_out.to_vec();
    check_tensor("w_out", &mut reference, &batch, Tensor::WOut, &w_out, 48);
}

#[test]
fn value_table_row_gradients_match_finite_differences() {
    let (t, batch) = trained_trainer_with_grads();
    let mut reference = RefModel::from_trainer(&t);
    let m = t.model.cfg.m;
    let rows: Vec<(u64, Vec<f32>)> = t
        .grads()
        .rows
        .iter()
        .map(|(&row, g)| (row, g.clone()))
        .collect();
    assert!(!rows.is_empty(), "no value rows were touched");
    let mut a = Vec::new();
    let mut n = Vec::new();
    for (row, grad) in rows.iter().take(24) {
        for i in 0..m {
            a.push(grad[i] as f64);
            n.push(numeric_grad(
                &mut reference,
                &batch,
                Tensor::Values,
                *row as usize * m + i,
            ));
        }
    }
    assert_grad_close("values", &a, &n, RTOL, ATOL);
    // an untouched row must have an exactly-zero numeric gradient (a
    // tiny torus under k_top = 232 *can* be fully covered; skip then)
    if let Some(untouched) =
        (0..t.model.table.rows()).find(|r| !t.grads().rows.contains_key(r))
    {
        let g =
            numeric_grad(&mut reference, &batch, Tensor::Values, untouched as usize * m);
        assert!(g.abs() <= ATOL, "untouched row {untouched} has gradient {g}");
    }
}

#[test]
fn frozen_routing_zeroes_exactly_the_routing_gradient() {
    // --freeze-routing must not silently change any *other* gradient
    let mut frozen =
        EngineTrainer::new(EngineTrainConfig { train_routing: false, ..grad_cfg() }).unwrap();
    let mut trained = EngineTrainer::new(grad_cfg()).unwrap();
    let batch = frozen.pipeline().train_batch(0);
    frozen.forward_backward(&batch).unwrap();
    trained.forward_backward(&batch).unwrap();
    assert!(frozen.grads().wq.iter().all(|&g| g == 0.0), "frozen wq grad must be zero");
    assert!(trained.grads().wq.iter().any(|&g| g != 0.0), "routing grad must flow");
    // value-table and suffix gradients are identical either way (the
    // routing path forks off upstream of them)
    assert_eq!(frozen.grads().wo, trained.grads().wo);
    assert_eq!(frozen.grads().w_out, trained.grads().w_out);
    assert_eq!(frozen.grads().rows, trained.grads().rows);
    // embeddings differ: routing adds its own dh term
    assert_ne!(frozen.grads().embed, trained.grads().embed);
}

#[test]
fn cached_routing_backward_is_bit_identical_to_the_recompute_path() {
    // The trainer's backward now replays the forward's captured
    // (d2, candidate) selections instead of re-running candidate
    // scoring + top-k per masked query.  The optimization contract is
    // *bit*-identity, not tolerance: at a training-shaped k_top
    // (truncation and padding both exercised), over a training-shaped
    // upstream gradient (most query rows zero), every gradient lane
    // must match the recompute path exactly.
    let torus = TorusK::new([16, 16, 8, 8, 8, 8, 8, 8]).unwrap();
    let mut table = ValueTable::zeros(1 << 18, 8).unwrap();
    table.randomize(21, 0.3);
    let mut rng = Rng::new(77);
    let n = 96;
    let queries: Vec<f64> = (0..n * 8).map(|_| rng.uniform(-9.0, 9.0)).collect();
    let mut dg = vec![0.0f32; n * 8];
    for qi in (0..n).step_by(4) {
        for v in dg[qi * 8..(qi + 1) * 8].iter_mut() {
            *v = rng.uniform(-1.0, 1.0) as f32;
        }
    }
    for threads in [1, 4] {
        let engine = BatchLookupEngine::with_threads(torus, 32, threads);
        let mut lk = BatchOutput::default();
        let mut gathered = vec![0.0f32; n * 8];
        let mut cache = BackwardCache::default();
        engine.lookup_gather_ragged_cached_into(
            &queries,
            &table,
            &mut lk,
            &mut gathered,
            &mut cache,
        );
        assert!(cache.matches(n, 32), "forward must validate the cache");
        let mut recomputed = vec![0.0f64; n * 8];
        engine.backward_gather_ragged_into(&queries, &table, &dg, &mut recomputed);
        let mut from_cache = vec![0.0f64; n * 8];
        engine.backward_gather_ragged_cached_into(&queries, &table, &dg, &cache, &mut from_cache);
        for (i, (a, b)) in from_cache.iter().zip(&recomputed).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{threads} threads, lane {i}: cached {a} vs recomputed {b}"
            );
        }
    }
}

// ---------------------------------------------------------------------
// convergence: the gradient is not just correct, it helps
// ---------------------------------------------------------------------

#[test]
fn trained_routing_reaches_lower_eval_loss_than_frozen() {
    let base = EngineTrainConfig {
        model: EngineConfig {
            max_batch: 4,
            seq_len: 12,
            width: 16,
            heads: 2,
            m: 8,
            k_top: 32,
            torus_k: [4; 8],
            threads: 1,
            ..EngineConfig::default()
        },
        steps: 100,
        batch: 4,
        vocab_size: 256,
        eval_batches: 8,
        ..EngineTrainConfig::default()
    };
    let mut frozen =
        EngineTrainer::new(EngineTrainConfig { train_routing: false, ..base.clone() })
            .unwrap();
    let mut trained = EngineTrainer::new(base).unwrap();
    for i in 0..100 {
        let lf = frozen.train_step().unwrap();
        let lt = trained.train_step().unwrap();
        assert!(lf.is_finite() && lt.is_finite(), "step {i}: {lf} / {lt}");
    }
    let ppl_frozen = frozen.evaluate(8).unwrap();
    let ppl_trained = trained.evaluate(8).unwrap();
    assert!(
        ppl_trained < ppl_frozen,
        "trained routing must beat frozen routing: {ppl_trained:.4} vs {ppl_frozen:.4}"
    );
}
