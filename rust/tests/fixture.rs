//! Cross-language consistency: the rust lattice implementation must agree
//! exactly with the python implementation that lowered the kernels, via
//! `artifacts/lattice_fixture.json` (written by `python -m compile.aot`).
//!
//! This is the contract that makes the split-mode gather sound: indices
//! computed inside the HLO (python math) address the rust memstore (rust
//! math).

use lram::lattice::{neighbor_table, LatticeLookup, TorusK};
use lram::util::json::{self, Json};

fn load_fixture() -> Option<Json> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/lattice_fixture.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(json::parse(&text).expect("fixture parses"))
}

macro_rules! require_fixture {
    () => {
        match load_fixture() {
            Some(f) => f,
            None => {
                eprintln!("skipping: artifacts/lattice_fixture.json missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn neighbor_tables_match() {
    let f = require_fixture!();
    let py: Vec<Vec<i64>> = f
        .req("neighbor_table")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| row.as_i64_vec().unwrap())
        .collect();
    let rs = neighbor_table();
    assert_eq!(py.len(), rs.len(), "table sizes differ");
    for (a, b) in py.iter().zip(rs.iter()) {
        assert_eq!(a.as_slice(), b.as_slice(), "neighbor table rows differ");
    }
}

#[test]
fn quantizer_matches() {
    let f = require_fixture!();
    for case in f.req("quantize").unwrap().as_arr().unwrap() {
        let q: Vec<f64> = case.req("q").unwrap().as_f64_vec().unwrap();
        let want: Vec<i64> = case.req("x").unwrap().as_i64_vec().unwrap();
        let got = lram::lattice::quantize(&q.clone().try_into().unwrap());
        assert_eq!(got.to_vec(), want, "quantize({q:?})");
    }
}

#[test]
fn torus_roundtrip_matches() {
    let f = require_fixture!();
    let k_vec = f.req("K").unwrap().as_i64_vec().unwrap();
    let torus = TorusK::new(k_vec.clone().try_into().unwrap()).unwrap();
    assert_eq!(
        torus.num_locations(),
        f.req("num_locations").unwrap().as_i64().unwrap() as u64
    );
    // python wrote representatives of evenly-spaced indices; rust must
    // map each back to an index consistent with its position
    let m = torus.num_locations();
    let stride = (m / 64).max(1);
    for (i, row) in f.req("torus_roundtrip").unwrap().as_arr().unwrap().iter().enumerate() {
        let x: Vec<i64> = row.as_i64_vec().unwrap();
        let idx = torus.index(&x.clone().try_into().unwrap());
        assert_eq!(idx, i as u64 * stride, "representative {x:?}");
    }
}

#[test]
fn compiled_kernel_matches_python_oracle() {
    // End-to-end HLO round-trip: run the AOT'd L1 kernel (lookup_check
    // artifact) on the fixture queries and compare the (index -> weight)
    // maps against the python brute-force oracle values.
    let f = require_fixture!();
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("lookup_check.meta.json").exists() {
        eprintln!("skipping: lookup_check artifact missing");
        return;
    }
    let rt = match lram::runtime::Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e:#})");
            return;
        }
    };
    let art = rt.load("lookup_check").unwrap();
    let mut state = art.zero_state().unwrap();
    let cases = f.req("lookups").unwrap().as_arr().unwrap();
    let n = cases.len().min(64);
    let mut q = vec![0.0f32; 64 * 8];
    for (i, case) in cases.iter().take(n).enumerate() {
        for (j, v) in case.req("q").unwrap().as_f64_vec().unwrap().iter().enumerate() {
            q[i * 8 + j] = *v as f32;
        }
    }
    let out = art
        .call(&mut state, &[lram::runtime::HostTensor::F32(q, vec![64, 8])])
        .unwrap();
    let idx = out[0].as_i32().unwrap();
    let wts = out[1].as_f32().unwrap();
    for (i, case) in cases.iter().take(n).enumerate() {
        let want_idx = case.req("idx").unwrap().as_i64_vec().unwrap();
        let want_w = case.req("w").unwrap().as_f64_vec().unwrap();
        let mut want: std::collections::HashMap<i64, f64> = Default::default();
        for (&wi, &ww) in want_idx.iter().zip(&want_w) {
            if ww > 1e-5 {
                *want.entry(wi).or_insert(0.0) += ww;
            }
        }
        let mut have: std::collections::HashMap<i64, f64> = Default::default();
        for k in 0..32 {
            let w = wts[i * 32 + k] as f64;
            if w > 1e-5 {
                *have.entry(idx[i * 32 + k] as i64).or_insert(0.0) += w;
            }
        }
        assert_eq!(
            want.keys().collect::<std::collections::BTreeSet<_>>(),
            have.keys().collect::<std::collections::BTreeSet<_>>(),
            "query {i}: compiled-kernel index set diverged from oracle"
        );
        for (k, w) in &want {
            assert!((have[k] - w).abs() < 1e-4, "query {i} slot {k}: {} vs {w}", have[k]);
        }
    }
}

#[test]
fn lookups_match_python_oracle() {
    let f = require_fixture!();
    let k_vec = f.req("K").unwrap().as_i64_vec().unwrap();
    let torus = TorusK::new(k_vec.try_into().unwrap()).unwrap();
    let mut lk = LatticeLookup::new(torus, 32);
    for case in f.req("lookups").unwrap().as_arr().unwrap() {
        let q: Vec<f64> = case.req("q").unwrap().as_f64_vec().unwrap();
        let want_idx: Vec<i64> = case.req("idx").unwrap().as_i64_vec().unwrap();
        let want_w: Vec<f64> = case.req("w").unwrap().as_f64_vec().unwrap();
        let got = lk.lookup(&q.clone().try_into().unwrap());
        // compare as index -> weight maps over nonzero weights (tie order
        // between equal weights is implementation-defined)
        let mut want: std::collections::HashMap<i64, f64> = Default::default();
        for (&i, &w) in want_idx.iter().zip(&want_w) {
            if w > 1e-9 {
                *want.entry(i).or_insert(0.0) += w;
            }
        }
        let mut have: std::collections::HashMap<i64, f64> = Default::default();
        for h in &got.hits {
            if h.weight > 1e-9 {
                *have.entry(h.index as i64).or_insert(0.0) += h.weight;
            }
        }
        assert_eq!(
            want.keys().collect::<std::collections::BTreeSet<_>>(),
            have.keys().collect::<std::collections::BTreeSet<_>>(),
            "index sets differ for q = {q:?}"
        );
        for (k, w) in &want {
            let h = have[k];
            assert!((h - w).abs() < 1e-6, "slot {k}: rust {h} vs python {w}");
        }
    }
}
