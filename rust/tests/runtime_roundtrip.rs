//! End-to-end runtime integration: load AOT artifacts, execute them on
//! the PJRT CPU client, and verify the training/eval/inference contracts.
//!
//! Requires `make artifacts` to have produced the core set; every test
//! skips gracefully when artifacts are absent so `cargo test` stays green
//! in a fresh checkout.

use std::sync::Arc;

use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::runtime::{HostTensor, Runtime};

fn runtime() -> Option<Arc<Runtime>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("train_step_baseline.meta.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    match Runtime::new(dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e:#})");
            None
        }
    }
}

macro_rules! require {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

fn pipeline_for(rt: &Runtime, artifact: &str) -> DataPipeline {
    let art = rt.load(artifact).unwrap();
    let b = art.manifest.batch.b;
    let s = art.manifest.batch.s;
    DataPipeline::new(CorpusSpec::default(), 4096, s, b, 0.15).unwrap()
}

fn batch_inputs(p: &DataPipeline, step: u64, with_step: bool) -> Vec<HostTensor> {
    let batch = p.train_batch(step);
    let (b, s) = (batch.b, batch.s);
    let mut v = Vec::new();
    if with_step {
        v.push(HostTensor::scalar_i32(step as i32));
    }
    v.push(HostTensor::I32(batch.tokens, vec![b, s]));
    v.push(HostTensor::I32(batch.targets, vec![b, s]));
    v.push(HostTensor::F32(batch.weights, vec![b, s]));
    v
}

#[test]
fn train_step_baseline_reduces_loss() {
    let rt = require!(runtime());
    let art = rt.load("train_step_baseline").unwrap();
    let mut state = art.initial_state().unwrap();
    let p = pipeline_for(&rt, "train_step_baseline");
    // repeat ONE batch: loss must drop markedly within a few steps
    let mut losses = Vec::new();
    for step in 0..6 {
        let mut inputs = batch_inputs(&p, 0, true);
        inputs[0] = HostTensor::scalar_i32(step);
        let out = art.step(&mut state, &inputs).unwrap();
        losses.push(out[0].as_f32().unwrap()[0]);
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(
        losses[5] < losses[0] - 0.1,
        "loss did not drop on a repeated batch: {losses:?}"
    );
}

#[test]
fn train_step_lram_memory_update_contract() {
    // With 8*96 positions x 12 heads x 32 hits per step the batch touches
    // nearly every one of the 2^14 slots (as the paper's Table 5 predicts
    // at >98% utilisation), so "sparsity" is not observable at this
    // geometry.  The testable contract is: a batch with all-zero loss
    // weights must leave the memory bit-identical (gradients vanish,
    // Adam moments stay zero), while a real batch must move it.
    let rt = require!(runtime());
    let art = rt.load("train_step_lram_small").unwrap();
    let mut state = art.initial_state().unwrap();
    let mem_pos = art
        .manifest
        .state
        .iter()
        .position(|s| s.name.contains("memory_values"))
        .expect("lram state has memory_values");
    let before = state.tensors[mem_pos].to_vec::<f32>().unwrap();
    let p = pipeline_for(&rt, "train_step_lram_small");

    // zero-weight batch: no position contributes to the loss
    let batch = p.train_batch(0);
    let (b, s) = (batch.b, batch.s);
    let inputs = vec![
        HostTensor::scalar_i32(0),
        HostTensor::I32(batch.tokens.clone(), vec![b, s]),
        HostTensor::I32(batch.targets.clone(), vec![b, s]),
        HostTensor::F32(vec![0.0; b * s], vec![b, s]),
    ];
    let out = art.step(&mut state, &inputs).unwrap();
    assert_eq!(out[0].as_f32().unwrap()[0], 0.0, "zero-weight loss");
    let after_zero = state.tensors[mem_pos].to_vec::<f32>().unwrap();
    assert_eq!(before, after_zero, "memory moved with zero loss weights");

    // real batch: the memory must move
    let inputs = batch_inputs(&p, 0, true);
    let out = art.step(&mut state, &inputs).unwrap();
    assert!(out[0].as_f32().unwrap()[0].is_finite());
    let after = state.tensors[mem_pos].to_vec::<f32>().unwrap();
    let dim = art.manifest.state[mem_pos].shape[1];
    let changed = (0..before.len() / dim)
        .filter(|&r| before[r * dim..(r + 1) * dim] != after[r * dim..(r + 1) * dim])
        .count();
    assert!(changed > 0, "memory never updated by a real batch");
}

#[test]
fn eval_loss_agrees_with_uniform_prior_at_init() {
    let rt = require!(runtime());
    let art = rt.load("eval_loss_baseline").unwrap();
    let mut state = art.initial_state().unwrap();
    let p = pipeline_for(&rt, "eval_loss_baseline");
    let inputs = batch_inputs(&p, 0, false);
    let out = art.call(&mut state, &inputs).unwrap();
    let nll = out[0].as_f32().unwrap()[0] as f64;
    let n = out[1].as_f32().unwrap()[0] as f64;
    assert!(n > 0.0);
    let mean = nll / n;
    // a fresh model is near the uniform prior ln(4096) = 8.32
    assert!((mean - (4096f64).ln()).abs() < 1.5, "mean nll {mean}");
}

#[test]
fn eval_loss_lram_reports_access_indices() {
    let rt = require!(runtime());
    let art = rt.load("eval_loss_lram_small").unwrap();
    assert!(art.manifest.access_outputs);
    let locations = art.manifest.locations.expect("manifest has locations") as i64;
    let mut state = art.initial_state().unwrap();
    let p = pipeline_for(&rt, "eval_loss_lram_small");
    let inputs = batch_inputs(&p, 0, false);
    let out = art.call(&mut state, &inputs).unwrap();
    let idx = out[2].as_i32().unwrap();
    let wts = out[3].as_f32().unwrap();
    assert_eq!(idx.len(), wts.len());
    assert!(!idx.is_empty());
    for (&i, &w) in idx.iter().zip(wts) {
        assert!((0..locations).contains(&(i as i64)), "index {i} out of range");
        assert!((0.0..=1.0 + 1e-5).contains(&w));
    }
    // top-32 weights per query should sum close to 1 (paper section 2.5)
    let k = art.manifest.k_top.unwrap_or(32);
    let sums: Vec<f32> = wts.chunks(k).map(|c| c.iter().sum()).collect();
    let mean: f32 = sums.iter().sum::<f32>() / sums.len() as f32;
    assert!(mean > 0.84 && mean <= 1.001, "mean total weight {mean}");
}

#[test]
fn infer_logits_are_log_probabilities() {
    let rt = require!(runtime());
    let art = rt.load("infer_logits_baseline").unwrap();
    let mut state = art.initial_state().unwrap();
    let b = art.manifest.batch.b;
    let s = art.manifest.inputs[0].shape[1];
    let tokens = vec![5i32; b * s];
    let out = art
        .call(&mut state, &[HostTensor::I32(tokens, vec![b, s])])
        .unwrap();
    let logp = out[0].as_f32().unwrap();
    let vocab = art.manifest.outputs[art.manifest.n_state_outputs].shape[2];
    assert_eq!(logp.len(), b * s * vocab);
    // each position's probabilities sum to 1
    let sum: f32 = logp[..vocab].iter().map(|l| l.exp()).sum();
    assert!((sum - 1.0).abs() < 1e-3, "sum p = {sum}");
}

#[test]
fn micro_artifacts_execute() {
    let rt = require!(runtime());
    // dense layer
    let art = rt.load("micro_dense_w256").unwrap();
    let mut state = art.initial_state_or_zeros().unwrap();
    let b = art.manifest.batch.b;
    let x = vec![0.1f32; b * 256];
    let out = art.call(&mut state, &[HostTensor::F32(x, vec![b, 256])]).unwrap();
    assert_eq!(out[0].shape(), &[b, 256]);
}
