//! Coordinator-level integration: Trainer + DataPipeline + artifacts,
//! checkpoint round-trip, Table-5 accounting path.

use std::sync::Arc;

use lram::config::TrainConfig;
use lram::coordinator::Trainer;
use lram::runtime::Runtime;

fn runtime() -> Option<Arc<Runtime>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("train_step_lram_small.meta.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    match Runtime::new(dir) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e:#})");
            None
        }
    }
}

macro_rules! require {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

fn cfg(variant: &str, run: &str) -> TrainConfig {
    let dir = std::env::temp_dir().join(format!("lram_run_{}_{run}", std::process::id()));
    TrainConfig {
        variant: variant.into(),
        artifact_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .display()
            .to_string(),
        run_dir: dir.display().to_string(),
        steps: 4,
        eval_every: 2,
        eval_batches: 2,
        ..TrainConfig::default()
    }
}

#[test]
fn trainer_runs_and_logs_curves() {
    let rt = require!(runtime());
    let mut trainer = Trainer::new(rt, cfg("lram_small", "curves")).unwrap();
    let out = trainer.run().unwrap();
    assert_eq!(out.steps, 4);
    assert!(out.final_train_loss.is_finite());
    assert!(out.final_val.perplexity.is_finite() && out.final_val.perplexity > 1.0);
    // Figure-2 CSV exists with a header + >= 2 eval rows
    let curve = std::fs::read_to_string(out.run_dir.join("valcurve.csv")).unwrap();
    assert!(curve.starts_with("step,val_ppl"));
    assert!(curve.lines().count() >= 3, "{curve}");
    // access accounting flowed through (lram variant)
    assert!(out.final_val.utilization.is_some());
    assert!(out.final_val.kl_divergence.is_some());
    let u = out.final_val.utilization.unwrap();
    assert!((0.0..=1.0).contains(&u));
    std::fs::remove_dir_all(&out.run_dir).ok();
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let rt = require!(runtime());
    let mut trainer = Trainer::new(rt.clone(), cfg("baseline", "ckpt")).unwrap();
    for _ in 0..2 {
        trainer.train_step().unwrap();
    }
    let before = trainer.evaluate_val().unwrap();
    let path = std::env::temp_dir().join(format!("lram_ckpt_{}.bin", std::process::id()));
    trainer.save_checkpoint(&path).unwrap();

    let mut fresh = Trainer::new(rt, cfg("baseline", "ckpt2")).unwrap();
    fresh.load_checkpoint(&path).unwrap();
    let after = fresh.evaluate_val().unwrap();
    assert!(
        (before.perplexity - after.perplexity).abs() < 1e-3,
        "{} vs {}",
        before.perplexity,
        after.perplexity
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn val_and_test_splits_are_distinct() {
    let rt = require!(runtime());
    let mut trainer = Trainer::new(rt, cfg("baseline", "splits")).unwrap();
    let val = trainer.evaluate_val().unwrap();
    let test = trainer.evaluate_test().unwrap();
    // both near the uniform prior at init, but computed over different
    // paragraphs -> not byte-identical
    assert!(val.perplexity.is_finite() && test.perplexity.is_finite());
    assert_ne!(val.perplexity.to_bits(), test.perplexity.to_bits());
}
