//! Chaos acceptance: the serving stack survives its own failures.
//!
//! Drives the failpoint seams end-to-end over a loopback socket: an
//! executor panic with a request in flight must produce a *well-formed*
//! 503 (never a hang, never a torn response), a visible restart in
//! `/stats`, recovery to ready on `/readyz`, and — because the
//! supervisor rebuilds the backend from the last good checkpoint —
//! bit-identical predictions after the fault.  A checkpoint-open error
//! injected into the first rebuild attempt additionally exercises the
//! capped-backoff retry loop.
//!
//! This lives in its own test binary (not `server_integration.rs`) on
//! purpose: the failpoint registry is process-global, and arming
//! `batcher.exec=panic` must never race another test's executor.  Tests
//! here serialise on a static mutex and clear every site on entry.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::model::LramMlm;
use lram::server::{
    BackendInit, Batcher, BatcherConfig, CheckpointInit, EngineConfig, HttpConfig, Server,
};
use lram::util::failpoint;
use lram::util::json;

// the failpoint registry is process-global: serialise every test and
// start each one from a clean (disarmed) slate
static GATE: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    let g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    failpoint::clear_all();
    g
}

fn build_small_bpe() -> Arc<lram::tokenizer::Bpe> {
    let p = DataPipeline::new(CorpusSpec::default(), 512, 8, 1, 0.15).unwrap();
    Arc::new(p.bpe)
}

/// Small engine config so tests spend milliseconds, not seconds; the
/// [4;8] torus keeps `values.bin` tiny enough for eager verification.
fn engine_cfg() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        seq_len: 24,
        width: 32,
        m: 32,
        torus_k: [4; 8],
        k_top: 8,
        ..EngineConfig::default()
    }
}

fn save_tiny_checkpoint(tag: &str, bpe: &lram::tokenizer::Bpe) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lram_chaos_ckpt_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let model = LramMlm::seeded(engine_cfg(), bpe.vocab_size()).unwrap();
    model.save_checkpoint(&dir, 3, &bpe.fingerprint(), None, None, false, 1).unwrap();
    dir
}

fn start_server(batcher: Arc<Batcher>, bpe: Arc<lram::tokenizer::Bpe>) -> Server {
    Server::bind("127.0.0.1:0", batcher, bpe, HttpConfig::default())
        .expect("binding an ephemeral port")
}

/// A persistent keep-alive client (write half + buffered read half).
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

struct Resp {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Resp {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Every error answer must be machine-actionable: parseable JSON
    /// carrying the structured envelope (docs/api.md) — an `error`
    /// object with a non-empty `code` and a `message`.
    fn assert_well_formed_error(&self) {
        let v = json::parse(&self.body)
            .unwrap_or_else(|e| panic!("unparseable error body {:?}: {e:#}", self.body));
        let err = v
            .get("error")
            .unwrap_or_else(|| panic!("error body missing 'error' object: {}", self.body));
        assert!(
            err.get("code").and_then(|c| c.as_str()).is_some_and(|c| !c.is_empty()),
            "error envelope missing 'code': {}",
            self.body
        );
        assert!(
            err.get("message").and_then(|m| m.as_str()).is_some(),
            "error envelope missing 'message': {}",
            self.body
        );
    }
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).expect("connecting to test server");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn roundtrip(&mut self, raw: &str) -> Resp {
        self.stream.write_all(raw.as_bytes()).expect("writing request");
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("reading status line");
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .unwrap_or_else(|| panic!("bad status line {line:?}"))
            .parse()
            .expect("numeric status");
        let mut headers = Vec::new();
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).expect("reading header");
            let h = h.trim();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
            }
        }
        let len: usize = headers
            .iter()
            .find(|(k, _)| k == "content-length")
            .map(|(_, v)| v.parse().expect("numeric content-length"))
            .expect("response carries Content-Length");
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).expect("reading body");
        Resp { status, headers, body: String::from_utf8(body).expect("utf-8 body") }
    }

    fn predict(&mut self, text: &str, top_k: usize) -> Resp {
        let body = format!(r#"{{"text": "{text}", "top_k": {top_k}}}"#);
        self.roundtrip(&format!(
            "POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ))
    }

    fn get(&mut self, path: &str) -> Resp {
        self.roundtrip(&format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
    }
}

/// The model's answer, stripped of per-request noise (`latency_ms`,
/// `batch_size` vary run to run; the masks array is the prediction).
fn masks_of(resp: &Resp) -> String {
    json::parse(&resp.body)
        .unwrap_or_else(|e| panic!("unparseable predict body {:?}: {e:#}", resp.body))
        .get("masks")
        .unwrap_or_else(|| panic!("predict body missing 'masks': {}", resp.body))
        .to_string()
}

/// Poll `f` until it returns true or `budget` elapses.
fn eventually(budget: Duration, what: &str, mut f: impl FnMut() -> bool) {
    let deadline = Instant::now() + budget;
    loop {
        if f() {
            return;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// The acceptance chaos test: executor panic with a request in flight,
/// plus a checkpoint read error injected into the first rebuild attempt.
/// Only well-formed responses, restart visible in `/stats`, recovery to
/// ready on `/readyz`, and bit-identical predictions afterwards.
#[test]
fn executor_panic_recovers_from_checkpoint_with_identical_predictions() {
    let _g = guard();
    let bpe = build_small_bpe();
    let dir = save_tiny_checkpoint("panic", &bpe);
    let batcher = Batcher::spawn(
        BackendInit::EngineCheckpoint(CheckpointInit::new(dir.to_str().unwrap())),
        bpe.clone(),
        BatcherConfig::default(),
    )
    .expect("checkpoint-backed batcher boots");
    let server = start_server(batcher, bpe);
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr);

    // pre-fault probe: the prediction we must reproduce after recovery
    let before = c.predict("the [MASK] of the", 3);
    assert_eq!(before.status, 200, "{}", before.body);
    let masks_before = masks_of(&before);
    assert_eq!(c.get("/readyz").status, 200);

    // arm: the next batch panics the executor, and the supervisor's
    // first rebuild attempt fails its checkpoint open (backoff retry)
    failpoint::set("batcher.exec", "panic:1.0:1").unwrap();
    failpoint::set("checkpoint.open", "error:1.0:1").unwrap();

    // the in-flight request must get a well-formed 503, not a hang or
    // a torn response
    let during = c.predict("the [MASK] of the", 3);
    assert_eq!(during.status, 503, "{}", during.body);
    during.assert_well_formed_error();
    assert!(
        during.header("retry-after").map(|v| v.parse::<u64>().is_ok()).unwrap_or(false),
        "503 must carry a numeric Retry-After"
    );
    assert_eq!(failpoint::fired("batcher.exec"), 1);

    // the restart becomes visible in /stats, then the backoff retry
    // succeeds and the health machine returns to ready
    eventually(Duration::from_secs(30), "restart counted in /stats", || {
        let stats = c.get("/stats");
        assert_eq!(stats.status, 200);
        let v = json::parse(&stats.body).expect("stats is JSON");
        v.get("restarts").and_then(|r| r.as_i64()).unwrap_or(0) >= 1
    });
    eventually(Duration::from_secs(30), "/readyz back to 200", || {
        c.get("/readyz").status == 200
    });
    assert_eq!(failpoint::fired("checkpoint.open"), 1, "rebuild must retry past the open error");

    // recovered backend came from the same checkpoint: bit-identical
    let after = c.predict("the [MASK] of the", 3);
    assert_eq!(after.status, 200, "{}", after.body);
    assert_eq!(masks_of(&after), masks_before, "post-recovery predictions must be bit-identical");

    let stats = c.get("/stats");
    let v = json::parse(&stats.body).expect("stats is JSON");
    assert_eq!(v.get("state").and_then(|s| s.as_str()), Some("ready"));
    assert_eq!(v.get("restarts").and_then(|r| r.as_i64()), Some(1));

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
    failpoint::clear_all();
}

/// An injected backend *error* (no panic) fails only that batch: the
/// requests in it get a well-formed 500, the executor keeps running,
/// and no restart is counted.
#[test]
fn injected_exec_error_fails_the_batch_without_a_restart() {
    let _g = guard();
    let bpe = build_small_bpe();
    let batcher =
        Batcher::spawn(BackendInit::Engine(engine_cfg()), bpe.clone(), BatcherConfig::default())
            .expect("engine backend needs no artifacts");
    let server = start_server(batcher, bpe);
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr);

    failpoint::set("batcher.exec", "error:1.0:1").unwrap();
    let failed = c.predict("the [MASK] of the", 3);
    assert_eq!(failed.status, 500, "{}", failed.body);
    failed.assert_well_formed_error();

    // same executor, no supervision event: the very next request works
    let ok = c.predict("the [MASK] of the", 3);
    assert_eq!(ok.status, 200, "{}", ok.body);
    let v = json::parse(&c.get("/stats").body).expect("stats is JSON");
    assert_eq!(v.get("restarts").and_then(|r| r.as_i64()), Some(0));
    assert_eq!(v.get("state").and_then(|s| s.as_str()), Some("ready"));

    server.shutdown();
    failpoint::clear_all();
}

/// Registry acceptance: the literal list below is this test's own copy
/// of the contract — it must stay in lockstep with the compiled-in
/// `failpoint::SITES` table (tidy check 4 additionally cross-checks the
/// table against production call sites and `docs/robustness.md`).  Every
/// registered site must be armable and must actually fire: arm each with
/// `error:1.0:1`, observe the injected error naming the site, and
/// observe the `times=1` budget disarming it.
#[test]
fn every_registered_failpoint_site_arms_fires_and_disarms() {
    let _g = guard();
    const EXPECTED: &[&str] = &[
        "checkpoint.open",
        "checkpoint.read_blob",
        "table.gather",
        "batcher.submit",
        "batcher.exec",
        "http.worker",
    ];
    let registered: Vec<&str> = failpoint::SITES.iter().map(|&(name, _)| name).collect();
    assert_eq!(
        registered, EXPECTED,
        "failpoint::SITES changed — update this test, docs/robustness.md, and \
         (for a new site) add a chaos scenario driving it end-to-end"
    );
    for &(site, purpose) in failpoint::SITES {
        assert!(!purpose.is_empty(), "site {site:?} needs a registered purpose");
        failpoint::set(site, "error:1.0:1").unwrap_or_else(|e| panic!("arming {site:?}: {e:#}"));
        let err = failpoint::inject(site)
            .unwrap_or_else(|| panic!("armed site {site:?} must fire at prob 1.0"));
        assert!(err.to_string().contains(site), "injected error must name its site: {err}");
        assert_eq!(failpoint::fired(site), 1, "{site:?} fired-count");
        assert!(
            failpoint::inject(site).is_none(),
            "times=1 must disarm {site:?} after its single firing"
        );
    }
    failpoint::clear_all();
}

/// A fault injected inside the HTTP worker's routing path answers 503
/// with Retry-After and a JSON body; the worker (and its connection
/// slot) survives to serve the next request.
#[test]
fn http_worker_failpoint_answers_well_formed_503_and_worker_survives() {
    let _g = guard();
    let bpe = build_small_bpe();
    let batcher =
        Batcher::spawn(BackendInit::Engine(engine_cfg()), bpe.clone(), BatcherConfig::default())
            .expect("engine backend needs no artifacts");
    let server = start_server(batcher, bpe);
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr);

    failpoint::set("http.worker", "error:1.0:1").unwrap();
    let faulted = c.get("/healthz");
    assert_eq!(faulted.status, 503, "{}", faulted.body);
    faulted.assert_well_formed_error();
    assert!(
        faulted.header("retry-after").map(|v| v.parse::<u64>().is_ok()).unwrap_or(false),
        "503 must carry a numeric Retry-After"
    );

    // times=1 disarmed the site; the same keep-alive connection recovers
    let ok = c.get("/healthz");
    assert_eq!(ok.status, 200, "{}", ok.body);
    assert!(ok.body.contains(r#""ok": true"#), "{}", ok.body);

    server.shutdown();
    failpoint::clear_all();
}
