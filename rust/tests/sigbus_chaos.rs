//! SIGBUS chaos: truncating a live mmap'd blob must never kill the
//! process.  The handler in `util::sigbus` remaps the faulting page with
//! zeros and bumps the process-wide fault epoch; serving notices the
//! epoch moved (the backend is *poisoned* — it may have computed on
//! zeros), answers the in-flight batch with a well-formed 503, and the
//! supervisor rebuilds from the newest verifying checkpoint — the
//! truncated directory fails verification, so the `.prev-<step>`
//! predecessor serves, with predictions bit-identical to pre-fault.
//!
//! Lives in its own test binary: the fault epoch is process-global, and
//! bumping it while another test's backend is live would poison that
//! backend.  Tests serialise on a static mutex.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::memstore::ValueTable;
use lram::model::LramMlm;
use lram::server::{
    BackendInit, Batcher, BatcherConfig, CheckpointInit, EngineConfig, HttpConfig, Server,
};
use lram::util::json;
use lram::util::sigbus;

// the SIGBUS fault epoch is process-global: a bump from one test would
// poison another test's live backend, so serialise
static GATE: Mutex<()> = Mutex::new(());

fn build_small_bpe() -> Arc<lram::tokenizer::Bpe> {
    let p = DataPipeline::new(CorpusSpec::default(), 512, 8, 1, 0.15).unwrap();
    Arc::new(p.bpe)
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        seq_len: 24,
        width: 32,
        m: 32,
        torus_k: [4; 8],
        k_top: 8,
        ..EngineConfig::default()
    }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lram_sigbus_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Shrink `path` to zero bytes in place — what a crashed writer, a full
/// disk repair, or an operator `truncate -s0` does to a mapped blob.
fn truncate_file(path: &Path) {
    std::fs::OpenOptions::new()
        .write(true)
        .open(path)
        .expect("opening blob for truncation")
        .set_len(0)
        .expect("truncating blob");
}

/// Contained fault, no serving stack: reads through a COW mapping whose
/// backing file vanished must observe zeros (not kill the process) and
/// must move the fault epoch.
#[test]
fn truncated_cow_mapping_reads_zeros_and_bumps_the_fault_epoch() {
    let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    let dir = temp_dir("unit");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("table.bin");
    let (rows, dim) = (1024u64, 8usize);
    let payload: Vec<u8> =
        (0..rows as usize * dim).flat_map(|i| (i as f32).to_le_bytes()).collect();
    std::fs::write(&path, &payload).unwrap();

    let table = ValueTable::open_cow(&path, rows, dim).unwrap();
    assert_eq!(table.row(3)[0], 24.0, "pre-truncation reads see file contents");

    let epoch_before = sigbus::fault_epoch();
    truncate_file(&path);
    // every page of the mapping is now past EOF: reads SIGBUS, the
    // handler remaps each faulting page with zeros, and we keep running
    let mut total = 0.0f32;
    for r in 0..rows {
        total += table.row(r).iter().sum::<f32>();
    }
    assert_eq!(total, 0.0, "post-truncation reads must observe zeros");
    assert!(
        sigbus::fault_epoch() > epoch_before,
        "containing a SIGBUS must advance the fault epoch"
    );

    drop(table);
    std::fs::remove_dir_all(&dir).ok();
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

struct Resp {
    status: u16,
    body: String,
}

impl Resp {
    fn assert_well_formed_error(&self) {
        let v = json::parse(&self.body)
            .unwrap_or_else(|e| panic!("unparseable error body {:?}: {e:#}", self.body));
        let err = v
            .get("error")
            .unwrap_or_else(|| panic!("error body missing 'error' object: {}", self.body));
        assert!(
            err.get("code").and_then(|c| c.as_str()).is_some_and(|c| !c.is_empty()),
            "error envelope missing 'code': {}",
            self.body
        );
        assert!(
            err.get("message").and_then(|m| m.as_str()).is_some(),
            "error envelope missing 'message': {}",
            self.body
        );
    }
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).expect("connecting to test server");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn roundtrip(&mut self, raw: &str) -> Resp {
        self.stream.write_all(raw.as_bytes()).expect("writing request");
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("reading status line");
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .unwrap_or_else(|| panic!("bad status line {line:?}"))
            .parse()
            .expect("numeric status");
        let mut len = 0usize;
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).expect("reading header");
            let h = h.trim();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    len = v.trim().parse().expect("numeric content-length");
                }
            }
        }
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).expect("reading body");
        Resp { status, body: String::from_utf8(body).expect("utf-8 body") }
    }

    fn predict(&mut self, text: &str, top_k: usize) -> Resp {
        let body = format!(r#"{{"text": "{text}", "top_k": {top_k}}}"#);
        self.roundtrip(&format!(
            "POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ))
    }

    fn get(&mut self, path: &str) -> Resp {
        self.roundtrip(&format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
    }
}

fn masks_of(resp: &Resp) -> String {
    json::parse(&resp.body)
        .unwrap_or_else(|e| panic!("unparseable predict body {:?}: {e:#}", resp.body))
        .get("masks")
        .unwrap_or_else(|| panic!("predict body missing 'masks': {}", resp.body))
        .to_string()
}

fn eventually(budget: Duration, what: &str, mut f: impl FnMut() -> bool) {
    let deadline = Instant::now() + budget;
    loop {
        if f() {
            return;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// The acceptance chaos scenario: truncate the live checkpoint's
/// `values.bin` mid-serve.  The faulting batch gets a well-formed 503,
/// the supervisor counts a restart, the truncated directory fails its
/// rebuild verification so the `.prev-<step>` predecessor serves, and
/// predictions come back bit-identical to pre-fault.
#[test]
fn truncating_the_mapped_value_table_mid_serve_recovers_via_prev_checkpoint() {
    let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    let bpe = build_small_bpe();
    let dir = temp_dir("serve");
    // save the SAME weights at steps 3 and 4 with keep=2: step 4 lives
    // in `dir`, its identical predecessor in `dir.prev-3` — the rebuild
    // fallback target once `dir` is corrupted
    let model = LramMlm::seeded(engine_cfg(), bpe.vocab_size()).unwrap();
    model.save_checkpoint(&dir, 3, &bpe.fingerprint(), None, None, false, 2).unwrap();
    model.save_checkpoint(&dir, 4, &bpe.fingerprint(), None, None, false, 2).unwrap();
    let prev = dir.with_file_name(format!(
        "{}.prev-3",
        dir.file_name().unwrap().to_str().unwrap()
    ));
    assert!(prev.is_dir(), "retention must have produced {prev:?}");

    let batcher = Batcher::spawn(
        BackendInit::EngineCheckpoint(CheckpointInit::new(dir.to_str().unwrap())),
        bpe.clone(),
        BatcherConfig::default(),
    )
    .expect("checkpoint-backed batcher boots");
    let server = Server::bind("127.0.0.1:0", batcher, bpe, HttpConfig::default())
        .expect("binding an ephemeral port");
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr);

    let before = c.predict("the [MASK] of the", 3);
    assert_eq!(before.status, 200, "{}", before.body);
    let masks_before = masks_of(&before);
    assert_eq!(c.get("/readyz").status, 200);

    // yank the mapped blob out from under the serving table
    truncate_file(&dir.join("values.bin"));

    // the faulting batch must 503 with a parseable error — never a hang,
    // a torn response, or (the old behaviour) SIGBUS killing the process
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let r = c.predict("the [MASK] of the", 3);
        if r.status == 503 {
            r.assert_well_formed_error();
            break;
        }
        assert_eq!(r.status, 200, "only 200 or a well-formed 503 allowed: {}", r.body);
        assert!(Instant::now() < deadline, "timed out waiting for the poisoned 503");
        std::thread::sleep(Duration::from_millis(20));
    }

    // supervision: restart counted, health back to ready
    eventually(Duration::from_secs(30), "restart counted in /stats", || {
        let v = json::parse(&c.get("/stats").body).expect("stats is JSON");
        v.get("restarts").and_then(|r| r.as_i64()).unwrap_or(0) >= 1
    });
    eventually(Duration::from_secs(30), "/readyz back to 200", || {
        c.get("/readyz").status == 200
    });

    // rebuilt from the identical .prev-3 predecessor: bit-identical
    let after = c.predict("the [MASK] of the", 3);
    assert_eq!(after.status, 200, "{}", after.body);
    assert_eq!(masks_of(&after), masks_before, "post-recovery predictions must be bit-identical");

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&prev).ok();
}
