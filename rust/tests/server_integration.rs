//! Serving-path integration: dynamic batcher over the inference artifact,
//! HTTP front door end-to-end on a loopback socket.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::server::{serve, Batcher, BatcherConfig, BatcherInit, PredictRequest};

fn artifact_dir() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("infer_logits_baseline.meta.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(dir.display().to_string())
}

macro_rules! require {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

fn build_bpe() -> Arc<lram::tokenizer::Bpe> {
    let p = DataPipeline::new(CorpusSpec::default(), 4096, 8, 1, 0.15).unwrap();
    Arc::new(p.bpe)
}

fn spawn_batcher(dir: &str) -> Option<Arc<Batcher>> {
    match Batcher::spawn(
        BatcherInit {
            artifact_dir: dir.to_string(),
            artifact_name: "infer_logits_baseline".into(),
            checkpoint: None,
        },
        build_bpe(),
        BatcherConfig::default(),
    ) {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e:#})");
            None
        }
    }
}

#[test]
fn batcher_answers_fill_mask_requests() {
    let dir = require!(artifact_dir());
    let batcher = require!(spawn_batcher(&dir));
    let bpe = build_bpe();
    let req = PredictRequest { text: "the [MASK] of the".into(), top_k: 5 };
    let resp = batcher.submit(&bpe, &req).unwrap();
    assert_eq!(resp.masks.len(), 1);
    assert_eq!(resp.masks[0].len(), 5);
    // log-probs descending and finite
    let lps: Vec<f64> = resp.masks[0].iter().map(|c| c.logprob).collect();
    for w in lps.windows(2) {
        assert!(w[0] >= w[1]);
    }
    assert!(lps.iter().all(|l| l.is_finite() && *l <= 0.0));
}

#[test]
fn batcher_coalesces_concurrent_requests() {
    let dir = require!(artifact_dir());
    let batcher = require!(spawn_batcher(&dir));
    let mut handles = vec![];
    for i in 0..4 {
        let b = batcher.clone();
        let bpe = build_bpe();
        handles.push(std::thread::spawn(move || {
            let req = PredictRequest {
                text: format!("request {i} says [MASK] ."),
                top_k: 3,
            };
            b.submit(&bpe, &req).unwrap()
        }));
    }
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.masks.len(), 1);
        assert_eq!(resp.masks[0].len(), 3);
    }
    let stats = batcher.stats.lock().unwrap().clone();
    assert_eq!(stats.requests, 4);
    assert!(stats.batches <= 4);
    assert!(stats.max_batch_fill >= 1);
}

#[test]
fn request_without_mask_errors() {
    let dir = require!(artifact_dir());
    let batcher = require!(spawn_batcher(&dir));
    let bpe = build_bpe();
    let req = PredictRequest { text: "no mask here".into(), top_k: 3 };
    assert!(batcher.submit(&bpe, &req).is_err());
}

#[test]
fn http_end_to_end() {
    let dir = require!(artifact_dir());
    let batcher = require!(spawn_batcher(&dir));
    let bpe = build_bpe();
    let addr = "127.0.0.1:18471";
    {
        let batcher = batcher.clone();
        let bpe = bpe.clone();
        std::thread::spawn(move || {
            let _ = serve(addr, batcher, bpe);
        });
    }
    // wait for the listener
    let mut stream = None;
    for _ in 0..50 {
        if let Ok(s) = TcpStream::connect(addr) {
            stream = Some(s);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let mut stream = stream.expect("server did not start");
    let body = r#"{"text": "the [MASK] sat", "top_k": 2}"#;
    write!(
        stream,
        "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("\"masks\""), "{resp}");

    // health endpoint
    let mut s2 = TcpStream::connect(addr).unwrap();
    write!(s2, "GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut r2 = String::new();
    s2.read_to_string(&mut r2).unwrap();
    assert!(r2.contains(r#"{"ok": true}"#), "{r2}");
}
