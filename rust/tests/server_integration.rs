//! Serving-path integration: dynamic batcher over a pluggable inference
//! backend, HTTP front door end-to-end on a loopback socket.
//!
//! The engine-backend tests run everywhere — no artifacts, no PJRT —
//! which is the point of the pure-rust serving path.  The artifact
//! tests still skip gracefully when compiled artifacts are absent.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::model::LramMlm;
use lram::server::{
    serve, ArtifactInit, BackendInit, Batcher, BatcherConfig, CheckpointInit, EngineBackend,
    EngineConfig, PredictRequest,
};

fn artifact_dir() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("infer_logits_baseline.meta.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(dir.display().to_string())
}

macro_rules! require {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

fn build_bpe() -> Arc<lram::tokenizer::Bpe> {
    let p = DataPipeline::new(CorpusSpec::default(), 4096, 8, 1, 0.15).unwrap();
    Arc::new(p.bpe)
}

/// Small tokenizer for the engine tests (they never skip, so debug-mode
/// runtime matters; the data-pipeline unit tests use the same scale).
fn build_small_bpe() -> Arc<lram::tokenizer::Bpe> {
    let p = DataPipeline::new(CorpusSpec::default(), 512, 8, 1, 0.15).unwrap();
    Arc::new(p.bpe)
}

fn spawn_artifact_batcher(dir: &str) -> Option<Arc<Batcher>> {
    match Batcher::spawn(
        BackendInit::Artifact(ArtifactInit {
            artifact_dir: dir.to_string(),
            artifact_name: "infer_logits_baseline".into(),
            checkpoint: None,
        }),
        build_bpe(),
        BatcherConfig::default(),
    ) {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e:#})");
            None
        }
    }
}

/// Small engine config so tests spend milliseconds, not seconds.
fn engine_cfg() -> EngineConfig {
    EngineConfig { max_batch: 4, seq_len: 24, width: 32, m: 32, ..EngineConfig::default() }
}

fn spawn_engine_batcher(bpe: Arc<lram::tokenizer::Bpe>) -> Arc<Batcher> {
    Batcher::spawn(BackendInit::Engine(engine_cfg()), bpe, BatcherConfig::default())
        .expect("engine backend needs no artifacts")
}

// ---------------------------------------------------------------------
// engine backend: runs everywhere, never skips
// ---------------------------------------------------------------------

#[test]
fn engine_batcher_answers_fill_mask_requests() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let req = PredictRequest { text: "the [MASK] of the".into(), top_k: 5 };
    let resp = batcher.submit(&bpe, &req).unwrap();
    assert_eq!(resp.masks.len(), 1);
    let scores = resp.masks[0].scores().expect("in-range mask is predicted");
    assert_eq!(scores.len(), 5);
    // log-probs descending and finite
    let lps: Vec<f64> = scores.iter().map(|c| c.logprob).collect();
    for w in lps.windows(2) {
        assert!(w[0] >= w[1]);
    }
    assert!(lps.iter().all(|l| l.is_finite() && *l <= 0.0));
    // true request latency: enqueue → reply includes the batch window
    assert!(resp.latency_ms > 0.0, "latency {}", resp.latency_ms);
    let stats = batcher.stats.lock().unwrap().clone();
    assert_eq!(stats.backend, "engine");
    assert_eq!(stats.requests, 1);
    assert!(stats.total_request_latency_ms >= stats.total_exec_latency_ms);
    let util = stats.memory_utilization.expect("engine backend tracks memory stats");
    assert!(util > 0.0, "no slots touched?");
}

#[test]
fn engine_batcher_coalesces_concurrent_requests() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let mut handles = vec![];
    for i in 0..4 {
        let b = batcher.clone();
        let bpe = bpe.clone();
        handles.push(std::thread::spawn(move || {
            let req = PredictRequest {
                text: format!("request {i} says [MASK] ."),
                top_k: 3,
            };
            b.submit(&bpe, &req).unwrap()
        }));
    }
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.masks.len(), 1);
        assert_eq!(resp.masks[0].scores().unwrap().len(), 3);
    }
    let stats = batcher.stats.lock().unwrap().clone();
    assert_eq!(stats.requests, 4);
    assert!(stats.batches <= 4);
    assert!(stats.max_batch_fill >= 1);
}

#[test]
fn engine_batcher_reports_truncated_masks() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    // enough filler words to push the second mask past seq_len = 24
    let mut text = String::from("the [MASK] sat");
    for _ in 0..40 {
        text.push_str(" cat");
    }
    text.push_str(" [MASK]");
    let resp = batcher.submit(&bpe, &PredictRequest { text, top_k: 3 }).unwrap();
    assert_eq!(resp.masks.len(), 2);
    assert!(resp.masks[0].scores().is_some(), "early mask predicted");
    assert!(resp.masks[1].is_truncated(), "late mask must be an explicit error");
    let stats = batcher.stats.lock().unwrap().clone();
    assert_eq!(stats.truncated_masks, 1);
}

#[test]
fn engine_request_without_mask_errors() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let req = PredictRequest { text: "no mask here".into(), top_k: 3 };
    assert!(batcher.submit(&bpe, &req).is_err());
}

#[test]
fn engine_http_end_to_end() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let addr = "127.0.0.1:18473";
    {
        let batcher = batcher.clone();
        let bpe = bpe.clone();
        std::thread::spawn(move || {
            let _ = serve(addr, batcher, bpe);
        });
    }
    let mut stream = None;
    for _ in 0..50 {
        if let Ok(s) = TcpStream::connect(addr) {
            stream = Some(s);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let mut stream = stream.expect("server did not start");
    let body = r#"{"text": "the [MASK] sat", "top_k": 2}"#;
    write!(
        stream,
        "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("\"masks\""), "{resp}");

    // stats endpoint reports the backend and memory observability
    let mut s2 = TcpStream::connect(addr).unwrap();
    write!(s2, "GET /stats HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut r2 = String::new();
    s2.read_to_string(&mut r2).unwrap();
    assert!(r2.contains(r#""backend": "engine""#), "{r2}");
    assert!(r2.contains("memory_utilization"), "{r2}");
}

#[test]
fn engine_backend_matches_scalar_oracle_end_to_end() {
    // the serving-path differential test: the full forward pass with the
    // fused batched engine must be bit-identical to the same pass with
    // the scalar LatticeLookup oracle driving the memory stage
    let cfg = engine_cfg();
    let seq_len = cfg.seq_len;
    let mut fused = EngineBackend::new(cfg.clone(), 4096).unwrap();
    let mut oracle = EngineBackend::new(cfg, 4096).unwrap();
    let tokens: Vec<i32> = (0..2 * seq_len as i32).map(|i| 5 + (i * 37) % 4000).collect();
    let a = fused.infer(&tokens).unwrap();
    let b = oracle.infer_with_scalar_oracle(&tokens).unwrap();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "logp {i}: {x} vs {y}");
    }
}

// ---------------------------------------------------------------------
// engine backend from a checkpoint: trained-weight serving path
// ---------------------------------------------------------------------

/// Save a seeded tiny model as a checkpoint stamped with `bpe`'s
/// fingerprint (the weights don't need to be *trained* for these server
/// tests — `checkpoint_roundtrip.rs` owns the trained-logits contract).
fn save_tiny_checkpoint(tag: &str, bpe: &lram::tokenizer::Bpe) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lram_srv_ckpt_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = EngineConfig { torus_k: [4; 8], k_top: 8, ..engine_cfg() };
    let model = LramMlm::seeded(cfg, bpe.vocab_size()).unwrap();
    model.save_checkpoint(&dir, 3, &bpe.fingerprint(), None).unwrap();
    dir
}

#[test]
fn tokenizer_hash_mismatch_is_a_clean_startup_error() {
    // checkpoint trained with tokenizer A, server pipeline builds
    // tokenizer B: Batcher::spawn must return Err (no panic, no serving)
    let train_bpe = build_small_bpe();
    let dir = save_tiny_checkpoint("mismatch", &train_bpe);
    let other = DataPipeline::new(CorpusSpec { seed: 99, ..CorpusSpec::default() }, 512, 8, 1, 0.15)
        .unwrap();
    let serving_bpe = Arc::new(other.bpe);
    assert_ne!(train_bpe.fingerprint(), serving_bpe.fingerprint(), "seeds must differ");
    let result = Batcher::spawn(
        BackendInit::EngineCheckpoint(CheckpointInit::new(dir.to_str().unwrap())),
        serving_bpe,
        BatcherConfig::default(),
    );
    let err = format!("{:#}", result.err().expect("mismatched tokenizer must refuse to serve"));
    assert!(err.contains("tokenizer"), "error must name the tokenizer: {err}");
    // the matching tokenizer still works
    let ok = Batcher::spawn(
        BackendInit::EngineCheckpoint(CheckpointInit::new(dir.to_str().unwrap())),
        train_bpe.clone(),
        BatcherConfig::default(),
    );
    assert!(ok.is_ok(), "{:?}", ok.err().map(|e| format!("{e:#}")));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stats_report_the_loaded_checkpoint_id() {
    let bpe = build_small_bpe();
    let dir = save_tiny_checkpoint("stats", &bpe);
    let expected_id =
        lram::checkpoint::Checkpoint::open(&dir).unwrap().manifest.checkpoint_id;
    let batcher = Batcher::spawn(
        BackendInit::EngineCheckpoint(CheckpointInit::new(dir.to_str().unwrap())),
        bpe.clone(),
        BatcherConfig::default(),
    )
    .unwrap();
    assert_eq!(
        batcher.stats.lock().unwrap().checkpoint.as_deref(),
        Some(expected_id.as_str())
    );

    // and over HTTP: /stats carries the id so operators can tell which
    // trained weights are live
    let addr = "127.0.0.1:18477";
    {
        let batcher = batcher.clone();
        let bpe = bpe.clone();
        std::thread::spawn(move || {
            let _ = serve(addr, batcher, bpe);
        });
    }
    let mut stream = None;
    for _ in 0..50 {
        if let Ok(s) = TcpStream::connect(addr) {
            stream = Some(s);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let mut s = stream.expect("server did not start");
    write!(s, "GET /stats HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    assert!(
        resp.contains(&format!(r#""checkpoint": "{expected_id}""#)),
        "/stats must name the checkpoint: {resp}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn seed_engine_requires_explicit_random_init_on_the_flag_path() {
    // the spawn_for_flag surface behind `lram serve`: engine without a
    // checkpoint must demand --random-init, and accept it when given
    let bpe = build_small_bpe();
    let artifact = ArtifactInit {
        artifact_dir: "does-not-exist".into(),
        artifact_name: "infer_logits_baseline".into(),
        checkpoint: None,
    };
    let refused = Batcher::spawn_for_flag(
        "engine",
        artifact.clone(),
        engine_cfg(),
        None,
        false,
        bpe.clone(),
        BatcherConfig::default(),
    );
    let err = format!("{:#}", refused.err().expect("seed weights need explicit opt-in"));
    assert!(err.contains("random-init"), "{err}");
    let accepted = Batcher::spawn_for_flag(
        "engine",
        artifact,
        engine_cfg(),
        None,
        true,
        bpe,
        BatcherConfig::default(),
    );
    assert!(accepted.is_ok());
    assert!(accepted.unwrap().stats.lock().unwrap().checkpoint.is_none());
}

// ---------------------------------------------------------------------
// artifact backend: exercises the PJRT path when artifacts exist
// ---------------------------------------------------------------------

#[test]
fn batcher_answers_fill_mask_requests() {
    let dir = require!(artifact_dir());
    let batcher = require!(spawn_artifact_batcher(&dir));
    let bpe = build_bpe();
    let req = PredictRequest { text: "the [MASK] of the".into(), top_k: 5 };
    let resp = batcher.submit(&bpe, &req).unwrap();
    assert_eq!(resp.masks.len(), 1);
    let scores = resp.masks[0].scores().unwrap();
    assert_eq!(scores.len(), 5);
    let lps: Vec<f64> = scores.iter().map(|c| c.logprob).collect();
    for w in lps.windows(2) {
        assert!(w[0] >= w[1]);
    }
    assert!(lps.iter().all(|l| l.is_finite() && *l <= 0.0));
}

#[test]
fn request_without_mask_errors() {
    let dir = require!(artifact_dir());
    let batcher = require!(spawn_artifact_batcher(&dir));
    let bpe = build_bpe();
    let req = PredictRequest { text: "no mask here".into(), top_k: 3 };
    assert!(batcher.submit(&bpe, &req).is_err());
}

#[test]
fn http_end_to_end() {
    let dir = require!(artifact_dir());
    let batcher = require!(spawn_artifact_batcher(&dir));
    let bpe = build_bpe();
    let addr = "127.0.0.1:18471";
    {
        let batcher = batcher.clone();
        let bpe = bpe.clone();
        std::thread::spawn(move || {
            let _ = serve(addr, batcher, bpe);
        });
    }
    // wait for the listener
    let mut stream = None;
    for _ in 0..50 {
        if let Ok(s) = TcpStream::connect(addr) {
            stream = Some(s);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let mut stream = stream.expect("server did not start");
    let body = r#"{"text": "the [MASK] sat", "top_k": 2}"#;
    write!(
        stream,
        "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("\"masks\""), "{resp}");

    // health endpoint
    let mut s2 = TcpStream::connect(addr).unwrap();
    write!(s2, "GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut r2 = String::new();
    s2.read_to_string(&mut r2).unwrap();
    assert!(r2.contains(r#"{"ok": true}"#), "{r2}");
}
