//! Serving-path integration: dynamic batcher over a pluggable inference
//! backend, event-driven keep-alive HTTP front door end-to-end on a
//! loopback socket — including bounded admission (429 + `Retry-After`
//! under overload, shed requests never reaching the backend), keep-alive
//! connection reuse, and graceful drain.
//!
//! The engine-backend tests run everywhere — no artifacts, no PJRT —
//! which is the point of the pure-rust serving path.  The artifact
//! tests still skip gracefully when compiled artifacts are absent.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::model::LramMlm;
use lram::server::{
    ArtifactInit, BackendInit, Batcher, BatcherConfig, CheckpointInit, EngineBackend,
    EngineConfig, HttpConfig, PredictRequest, Server, SubmitError,
};

fn artifact_dir() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("infer_logits_baseline.meta.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(dir.display().to_string())
}

macro_rules! require {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

fn build_bpe() -> Arc<lram::tokenizer::Bpe> {
    let p = DataPipeline::new(CorpusSpec::default(), 4096, 8, 1, 0.15).unwrap();
    Arc::new(p.bpe)
}

/// Small tokenizer for the engine tests (they never skip, so debug-mode
/// runtime matters; the data-pipeline unit tests use the same scale).
fn build_small_bpe() -> Arc<lram::tokenizer::Bpe> {
    let p = DataPipeline::new(CorpusSpec::default(), 512, 8, 1, 0.15).unwrap();
    Arc::new(p.bpe)
}

fn spawn_artifact_batcher(dir: &str) -> Option<Arc<Batcher>> {
    match Batcher::spawn(
        BackendInit::Artifact(ArtifactInit {
            artifact_dir: dir.to_string(),
            artifact_name: "infer_logits_baseline".into(),
            checkpoint: None,
        }),
        build_bpe(),
        BatcherConfig::default(),
    ) {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e:#})");
            None
        }
    }
}

/// Small engine config so tests spend milliseconds, not seconds.
fn engine_cfg() -> EngineConfig {
    EngineConfig { max_batch: 4, seq_len: 24, width: 32, m: 32, ..EngineConfig::default() }
}

fn spawn_engine_batcher(bpe: Arc<lram::tokenizer::Bpe>) -> Arc<Batcher> {
    Batcher::spawn(BackendInit::Engine(engine_cfg()), bpe, BatcherConfig::default())
        .expect("engine backend needs no artifacts")
}

/// Bind the front door on an ephemeral loopback port.
fn start_server(batcher: Arc<Batcher>, bpe: Arc<lram::tokenizer::Bpe>) -> Server {
    start_server_with(batcher, bpe, HttpConfig::default())
}

fn start_server_with(
    batcher: Arc<Batcher>,
    bpe: Arc<lram::tokenizer::Bpe>,
    cfg: HttpConfig,
) -> Server {
    Server::bind("127.0.0.1:0", batcher, bpe, cfg).expect("binding an ephemeral port")
}

/// A persistent client connection: write half + buffered read half.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

/// One parsed HTTP response (headers lowercased).
struct Resp {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Resp {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).expect("connecting to test server");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    /// Send one request and read exactly one response, leaving the
    /// connection open (keep-alive).
    fn roundtrip(&mut self, raw: &str) -> Resp {
        self.stream.write_all(raw.as_bytes()).expect("writing request");
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("reading status line");
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .unwrap_or_else(|| panic!("bad status line {line:?}"))
            .parse()
            .expect("numeric status");
        let mut headers = Vec::new();
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).expect("reading header");
            let h = h.trim();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
            }
        }
        let len: usize = headers
            .iter()
            .find(|(k, _)| k == "content-length")
            .map(|(_, v)| v.parse().expect("numeric content-length"))
            .expect("response carries Content-Length");
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).expect("reading body");
        Resp { status, headers, body: String::from_utf8(body).expect("utf-8 body") }
    }

    fn predict(&mut self, text: &str, top_k: usize) -> Resp {
        let body = format!(r#"{{"text": "{text}", "top_k": {top_k}}}"#);
        self.roundtrip(&format!(
            "POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ))
    }

    fn get(&mut self, path: &str) -> Resp {
        self.roundtrip(&format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
    }
}

// ---------------------------------------------------------------------
// engine backend: runs everywhere, never skips
// ---------------------------------------------------------------------

#[test]
fn engine_batcher_answers_fill_mask_requests() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let req = PredictRequest { text: "the [MASK] of the".into(), top_k: 5 };
    let resp = batcher.submit(&bpe, &req).unwrap();
    assert_eq!(resp.masks.len(), 1);
    let scores = resp.masks[0].scores().expect("in-range mask is predicted");
    assert_eq!(scores.len(), 5);
    // log-probs descending and finite
    let lps: Vec<f64> = scores.iter().map(|c| c.logprob).collect();
    for w in lps.windows(2) {
        assert!(w[0] >= w[1]);
    }
    assert!(lps.iter().all(|l| l.is_finite() && *l <= 0.0));
    // true request latency: enqueue → reply includes the batch window
    assert!(resp.latency_ms > 0.0, "latency {}", resp.latency_ms);
    let stats = batcher.stats.lock().unwrap().clone();
    assert_eq!(stats.backend, "engine");
    assert_eq!(stats.requests, 1);
    assert!(stats.total_request_latency_ms >= stats.total_exec_latency_ms);
    // the latency histogram saw the same request
    assert_eq!(stats.latency.count(), 1);
    assert!(stats.latency.percentile_ms(0.5) > 0.0);
    let memory = stats.memory.expect("engine backend tracks memory stats");
    assert!(memory.utilization > 0.0, "no slots touched?");
    assert!(!memory.per_shard.is_empty(), "per-shard breakdown always present");
    // nothing shed, nothing left in the queue
    assert_eq!(stats.shed, 0);
    assert_eq!(batcher.queue_depth(), 0);
}

#[test]
fn engine_batcher_coalesces_concurrent_requests() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let mut handles = vec![];
    for i in 0..4 {
        let b = batcher.clone();
        let bpe = bpe.clone();
        handles.push(std::thread::spawn(move || {
            let req = PredictRequest {
                text: format!("request {i} says [MASK] ."),
                top_k: 3,
            };
            b.submit(&bpe, &req).unwrap()
        }));
    }
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.masks.len(), 1);
        assert_eq!(resp.masks[0].scores().unwrap().len(), 3);
    }
    let stats = batcher.stats.lock().unwrap().clone();
    assert_eq!(stats.requests, 4);
    assert!(stats.batches <= 4);
    assert!(stats.max_batch_fill >= 1);
}

#[test]
fn engine_batcher_reports_truncated_masks() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    // enough filler words to push the second mask past seq_len = 24
    let mut text = String::from("the [MASK] sat");
    for _ in 0..40 {
        text.push_str(" cat");
    }
    text.push_str(" [MASK]");
    let resp = batcher.submit(&bpe, &PredictRequest { text, top_k: 3 }).unwrap();
    assert_eq!(resp.masks.len(), 2);
    assert!(resp.masks[0].scores().is_some(), "early mask predicted");
    assert!(resp.masks[1].is_truncated(), "late mask must be an explicit error");
    let stats = batcher.stats.lock().unwrap().clone();
    assert_eq!(stats.truncated_masks, 1);
}

#[test]
fn engine_request_without_mask_errors() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let req = PredictRequest { text: "no mask here".into(), top_k: 3 };
    match batcher.submit_bounded(&bpe, &req) {
        Err(SubmitError::BadRequest(m)) => assert!(m.contains("[MASK]"), "{m}"),
        other => panic!("expected BadRequest, got {other:?}"),
    }
    // the rejection released its admission slot
    assert_eq!(batcher.queue_depth(), 0);
    // and the flattening wrapper still errors
    assert!(batcher.submit(&bpe, &req).is_err());
}

#[test]
fn engine_http_end_to_end() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let server = start_server(batcher, bpe);
    let addr = server.local_addr().to_string();

    let mut c = Client::connect(&addr);
    let resp = c.predict("the [MASK] sat", 2);
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert!(resp.body.contains("\"masks\""), "{}", resp.body);

    // stats endpoint reports the backend, front-door counters, latency
    // percentiles and memory observability — over the same connection
    let stats = c.get("/stats");
    assert_eq!(stats.status, 200);
    let body = stats.body;
    assert!(body.starts_with(r#"{"schema_version": 1"#), "{body}");
    assert!(body.contains(r#""backend": "engine""#), "{body}");
    assert!(body.contains("memory_utilization"), "{body}");
    assert!(body.contains(r#""shards": [{"shard": 0"#), "{body}");
    assert!(body.contains("latency_p50_ms"), "{body}");
    assert!(body.contains("latency_p99_ms"), "{body}");
    assert!(body.contains("queue_depth"), "{body}");
    assert!(body.contains("http_workers"), "{body}");
    // it parses as JSON, and the front door saw exactly one connection
    let v = lram::util::json::parse(&body).unwrap();
    assert_eq!(v.get("connections_accepted").unwrap().as_usize().unwrap(), 1);
    assert!(v.get("http_requests").unwrap().as_usize().unwrap() >= 2);
    assert_eq!(v.get("shed").unwrap().as_usize().unwrap(), 0);
    server.shutdown();
}

#[test]
fn keep_alive_connection_serves_many_requests_on_one_socket() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let server = start_server(batcher, bpe);
    let addr = server.local_addr().to_string();
    let http = server.http_stats();

    let mut c = Client::connect(&addr);
    for i in 0..3 {
        let resp = c.predict(&format!("round {i} the [MASK] sat"), 2);
        assert_eq!(resp.status, 200, "request {i}: {}", resp.body);
        assert_eq!(
            resp.header("connection"),
            Some("keep-alive"),
            "response must advertise keep-alive"
        );
        assert!(resp.header("keep-alive").is_some(), "Keep-Alive header with the timeout");
    }
    let health = c.get("/healthz");
    assert_eq!(health.status, 200);

    use std::sync::atomic::Ordering;
    assert_eq!(
        http.connections_accepted.load(Ordering::Relaxed),
        1,
        "four requests must reuse one connection"
    );
    assert_eq!(http.requests.load(Ordering::Relaxed), 4);
    server.shutdown();
}

#[test]
fn connection_close_is_honored_on_request() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let server = start_server(batcher, bpe);
    let addr = server.local_addr().to_string();

    let mut stream = TcpStream::connect(&addr).unwrap();
    let body = r#"{"text": "the [MASK] sat", "top_k": 2}"#;
    write!(
        stream,
        "POST /predict HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    // the server closes after responding, so read_to_string terminates
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("Connection: close"), "{resp}");
    assert!(resp.contains("\"masks\""), "{resp}");
    server.shutdown();
}

#[test]
fn overload_sheds_429_with_retry_after_and_never_reaches_backend() {
    let bpe = build_small_bpe();
    // admission cap of 1 and a long batch window: the first request
    // parks in the batcher for ~400ms, every request arriving meanwhile
    // must shed
    let batcher = Batcher::spawn(
        BackendInit::Engine(engine_cfg()),
        bpe.clone(),
        BatcherConfig {
            max_wait: Duration::from_millis(400),
            max_pending: 1,
            ..BatcherConfig::default()
        },
    )
    .unwrap();
    let server = start_server(batcher.clone(), bpe.clone());
    let addr = server.local_addr().to_string();

    // occupy the single admission slot
    let first = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&addr);
            c.predict("the [MASK] sat", 2).status
        })
    };
    // wait until the first request actually holds the admission slot
    // (queue_depth counts admitted-but-unreplied requests), so the
    // sheds below are deterministic, not a race
    for _ in 0..100 {
        if batcher.queue_depth() == 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(batcher.queue_depth(), 1, "first request admitted and in flight");

    let mut c = Client::connect(&addr);
    for i in 0..2 {
        let resp = c.predict("the [MASK] sat", 2);
        assert_eq!(resp.status, 429, "request {i} must shed: {}", resp.body);
        // a well-formed shed: Retry-After header + JSON error body.  The
        // value is adaptive (queue depth x mean batch latency, floored
        // at 1 — see Batcher::retry_after_secs; growth under deeper
        // queues is pinned down by the estimator's unit tests)
        let retry = resp.header("retry-after").expect("429 carries Retry-After");
        let secs: u64 = retry.parse().unwrap_or_else(|_| {
            panic!("Retry-After '{retry}' must be whole seconds")
        });
        assert!((1..=60).contains(&secs), "Retry-After {secs} outside [1, 60]");
        let v = lram::util::json::parse(&resp.body).expect("429 body is JSON");
        let err = v.get("error").expect("structured error envelope");
        assert_eq!(err.get("code").unwrap().as_str().unwrap(), "overloaded", "{}", resp.body);
        assert!(err.get("message").unwrap().as_str().is_some(), "{}", resp.body);
        // the body mirrors the Retry-After header so JSON-only clients
        // see the backoff hint too
        let body_secs = err.get("retry_after_s").unwrap().as_f64().unwrap() as u64;
        assert!((1..=60).contains(&body_secs), "{}", resp.body);
        // shedding must not kill the keep-alive connection (the client
        // is told when to retry, on the same socket) — proven by the
        // next loop iteration reusing `c`
    }
    assert_eq!(first.join().unwrap(), 200, "the admitted request completes fine");

    let stats = batcher.stats.lock().unwrap().clone();
    assert_eq!(stats.shed, 2, "both overflow requests counted as shed");
    assert_eq!(
        stats.requests, 1,
        "shed requests must never reach the backend (only the admitted one did)"
    );
    assert_eq!(batcher.queue_depth(), 0, "slots all released");
    server.shutdown();
}

#[test]
fn concurrent_keep_alive_clients_are_served_without_error() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let server = start_server_with(
        batcher,
        bpe,
        HttpConfig { workers: 8, ..HttpConfig::default() },
    );
    let addr = server.local_addr().to_string();
    let http = server.http_stats();

    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 4;
    let mut handles = vec![];
    for cid in 0..CLIENTS {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr);
            for r in 0..PER_CLIENT {
                let resp = c.predict(&format!("client {cid} round {r} [MASK] ."), 3);
                assert_eq!(resp.status, 200, "client {cid} round {r}: {}", resp.body);
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked");
    }
    use std::sync::atomic::Ordering;
    assert_eq!(http.connections_accepted.load(Ordering::Relaxed), CLIENTS as u64);
    assert_eq!(http.requests.load(Ordering::Relaxed), (CLIENTS * PER_CLIENT) as u64);
    assert_eq!(http.connections_shed.load(Ordering::Relaxed), 0);
    server.shutdown();
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    let bpe = build_small_bpe();
    // a wide batch window keeps the request in flight while we shut down
    let batcher = Batcher::spawn(
        BackendInit::Engine(engine_cfg()),
        bpe.clone(),
        BatcherConfig { max_wait: Duration::from_millis(300), ..BatcherConfig::default() },
    )
    .unwrap();
    let server = start_server(batcher, bpe);
    let addr = server.local_addr().to_string();

    let inflight = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&addr);
            let resp = c.predict("the [MASK] sat", 2);
            (resp.status, resp.body)
        })
    };
    // let the request reach the batcher, then drain while it waits for
    // batch-mates
    std::thread::sleep(Duration::from_millis(100));
    server.shutdown();

    let (status, body) = inflight.join().expect("in-flight client must not be dropped");
    assert_eq!(status, 200, "in-flight request completes during drain: {body}");
    assert!(body.contains("\"masks\""), "{body}");

    // after the drain the listener is gone: new connections are refused
    // (or at best connect and then fail immediately)
    match TcpStream::connect(&addr) {
        Err(_) => {}
        Ok(mut s) => {
            let _ = write!(s, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
            let mut buf = String::new();
            s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            assert!(
                s.read_to_string(&mut buf).map(|n| n == 0).unwrap_or(true),
                "a drained server must not serve: {buf}"
            );
        }
    }
}

#[test]
fn sigterm_drains_in_flight_requests_then_stops_the_server() {
    // the `lram serve` kill path: SIGTERM → sigaction handler → flag →
    // watcher → graceful drain.  An in-flight request must complete
    // with a full 200 and the server must actually stop afterwards.
    let bpe = build_small_bpe();
    // a wide batch window keeps the request in flight while the signal
    // lands (same trick as the graceful-shutdown test)
    let batcher = Batcher::spawn(
        BackendInit::Engine(engine_cfg()),
        bpe.clone(),
        BatcherConfig { max_wait: Duration::from_millis(400), ..BatcherConfig::default() },
    )
    .unwrap();
    let server = start_server(batcher, bpe);
    let addr = server.local_addr().to_string();
    // install the handler BEFORE raising, or the default disposition
    // (terminate the whole test process) applies
    server.drain_on_termination().expect("installing the SIGTERM handler");

    let inflight = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&addr);
            let resp = c.predict("the [MASK] sat", 2);
            (resp.status, resp.body)
        })
    };
    // let the request reach the batcher, then deliver the real signal
    std::thread::sleep(Duration::from_millis(100));
    lram::util::signal::raise_sigterm();

    let (status, body) = inflight.join().expect("in-flight client must not be dropped");
    assert_eq!(status, 200, "in-flight request completes during the drain: {body}");
    assert!(body.contains("\"masks\""), "{body}");

    // the signal must stop the server: join() returns instead of
    // blocking forever (bounded here so a regression fails, not hangs)
    let joined = std::thread::spawn(move || server.join());
    let t0 = std::time::Instant::now();
    while !joined.is_finished() && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(joined.is_finished(), "server did not stop after SIGTERM");
    joined.join().unwrap();

    // and the listener is gone.  If connect() still succeeds (backlog
    // remnants), the strong check is that no actual HTTP response comes
    // back — a timeout or reset masks nothing here, because a live
    // server would have answered /healthz within the 2s window
    match TcpStream::connect(&addr) {
        Err(_) => {}
        Ok(mut s) => {
            let _ = write!(s, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
            s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            let mut got = Vec::new();
            let mut chunk = [0u8; 1024];
            loop {
                match s.read(&mut chunk) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => got.extend_from_slice(&chunk[..n]),
                }
            }
            let text = String::from_utf8_lossy(&got);
            assert!(
                !text.starts_with("HTTP/1.1 200"),
                "a SIGTERM-drained server must not serve: {text}"
            );
        }
    }
}

#[test]
fn engine_backend_matches_scalar_oracle_end_to_end() {
    // the serving-path differential test: the full forward pass with the
    // fused batched engine must be bit-identical to the same pass with
    // the scalar LatticeLookup oracle driving the memory stage
    let cfg = engine_cfg();
    let seq_len = cfg.seq_len;
    let mut fused = EngineBackend::new(cfg.clone(), 4096).unwrap();
    let mut oracle = EngineBackend::new(cfg, 4096).unwrap();
    let tokens: Vec<i32> = (0..2 * seq_len as i32).map(|i| 5 + (i * 37) % 4000).collect();
    let a = fused.infer(&tokens).unwrap();
    let b = oracle.infer_with_scalar_oracle(&tokens).unwrap();
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "logp {i}: {x} vs {y}");
    }
}

#[test]
fn sharded_serving_is_bit_identical_to_single_shard_over_http() {
    // the sharding acceptance test, through the full HTTP boundary: an
    // engine server whose value table is partitioned across 4 shard
    // workers must answer with exactly the same bytes as the fused
    // single-owner path on the bit-exact f64 path.  Ragged shapes —
    // varying lengths, multiple masks, truncation — all route through
    // the staged score/select/merge/gather pipeline, so any divergence
    // in merge order or per-shard gather shows up as a byte diff here.
    let bpe = build_small_bpe();
    // a small torus so the tiny batch actually spreads across owners
    let cfg = EngineConfig { torus_k: [4; 8], k_top: 8, ..engine_cfg() };
    let spawn = |shards: usize| {
        let cfg = EngineConfig { shards, ..cfg.clone() };
        let b = Batcher::spawn(BackendInit::Engine(cfg), bpe.clone(), BatcherConfig::default())
            .expect("engine backend needs no artifacts");
        start_server(b, bpe.clone())
    };
    let one = spawn(1);
    let four = spawn(4);
    let mut c1 = Client::connect(&one.local_addr().to_string());
    let mut c4 = Client::connect(&four.local_addr().to_string());
    // masks-only prefix: everything before the latency field, which is
    // wall-clock and legitimately differs between the two servers
    let masks_of = |body: &str| {
        let end = body.find(r#", "latency_ms""#).expect("response carries latency");
        body[..end].to_string()
    };
    let mut texts: Vec<String> = vec![
        "the [MASK] sat".into(),
        "a [MASK] and a [MASK] walked into the [MASK] .".into(),
        "[MASK]".into(),
        "one more [MASK] for the long and winding road , [MASK] says".into(),
    ];
    // push a late mask past seq_len = 24: the truncation error object
    // must also be identical across shard counts
    let mut long = String::from("the [MASK] sat");
    for _ in 0..40 {
        long.push_str(" cat");
    }
    long.push_str(" [MASK]");
    texts.push(long);
    for (i, text) in texts.iter().enumerate() {
        let body = format!(r#"{{"text": "{text}", "top_k": 6}}"#);
        // alternate the canonical route and its legacy alias — the
        // comparison also proves the two routes serve the same handler
        let path = if i % 2 == 0 { "/v1/predict" } else { "/predict" };
        let req = format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let a = c1.roundtrip(&req);
        let b = c4.roundtrip(&req);
        assert_eq!(a.status, 200, "shards=1 {path}: {}", a.body);
        assert_eq!(b.status, 200, "shards=4 {path}: {}", b.body);
        assert_eq!(
            masks_of(&a.body),
            masks_of(&b.body),
            "request {i} ({text:?}) diverged between 1 and 4 shards"
        );
    }
    // the sharded server reports its partition in /stats
    let stats = c4.get("/stats");
    let v = lram::util::json::parse(&stats.body).unwrap();
    assert_eq!(v.get("schema_version").unwrap().as_usize().unwrap(), 1);
    let shards = v.get("shards").expect("sharded /stats breakdown").as_arr().unwrap();
    assert_eq!(shards.len(), 4, "{}", stats.body);
    one.shutdown();
    four.shutdown();
}

// ---------------------------------------------------------------------
// engine backend from a checkpoint: trained-weight serving path
// ---------------------------------------------------------------------

/// Save a seeded tiny model as a checkpoint stamped with `bpe`'s
/// fingerprint (the weights don't need to be *trained* for these server
/// tests — `checkpoint_roundtrip.rs` owns the trained-logits contract).
fn save_tiny_checkpoint(tag: &str, bpe: &lram::tokenizer::Bpe) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lram_srv_ckpt_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = EngineConfig { torus_k: [4; 8], k_top: 8, ..engine_cfg() };
    let model = LramMlm::seeded(cfg, bpe.vocab_size()).unwrap();
    model.save_checkpoint(&dir, 3, &bpe.fingerprint(), None, None, false, 1).unwrap();
    dir
}

#[test]
fn tokenizer_hash_mismatch_is_a_clean_startup_error() {
    // checkpoint trained with tokenizer A, server pipeline builds
    // tokenizer B: Batcher::spawn must return Err (no panic, no serving)
    let train_bpe = build_small_bpe();
    let dir = save_tiny_checkpoint("mismatch", &train_bpe);
    let other = DataPipeline::new(CorpusSpec { seed: 99, ..CorpusSpec::default() }, 512, 8, 1, 0.15)
        .unwrap();
    let serving_bpe = Arc::new(other.bpe);
    assert_ne!(train_bpe.fingerprint(), serving_bpe.fingerprint(), "seeds must differ");
    let result = Batcher::spawn(
        BackendInit::EngineCheckpoint(CheckpointInit::new(dir.to_str().unwrap())),
        serving_bpe,
        BatcherConfig::default(),
    );
    let err = format!("{:#}", result.err().expect("mismatched tokenizer must refuse to serve"));
    assert!(err.contains("tokenizer"), "error must name the tokenizer: {err}");
    // the matching tokenizer still works
    let ok = Batcher::spawn(
        BackendInit::EngineCheckpoint(CheckpointInit::new(dir.to_str().unwrap())),
        train_bpe.clone(),
        BatcherConfig::default(),
    );
    assert!(ok.is_ok(), "{:?}", ok.err().map(|e| format!("{e:#}")));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stats_report_the_loaded_checkpoint_id() {
    let bpe = build_small_bpe();
    let dir = save_tiny_checkpoint("stats", &bpe);
    let expected_id =
        lram::checkpoint::Checkpoint::open(&dir).unwrap().manifest.checkpoint_id;
    let batcher = Batcher::spawn(
        BackendInit::EngineCheckpoint(CheckpointInit::new(dir.to_str().unwrap())),
        bpe.clone(),
        BatcherConfig::default(),
    )
    .unwrap();
    assert_eq!(
        batcher.stats.lock().unwrap().checkpoint.as_deref(),
        Some(expected_id.as_str())
    );

    // and over HTTP: /stats carries the id so operators can tell which
    // trained weights are live
    let server = start_server(batcher, bpe);
    let mut c = Client::connect(&server.local_addr().to_string());
    let resp = c.get("/stats");
    assert!(
        resp.body.contains(&format!(r#""checkpoint": "{expected_id}""#)),
        "/stats must name the checkpoint: {}",
        resp.body
    );
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_latest_checkpoint_falls_back_to_predecessor_when_serving() {
    // the crash-recovery chain end to end: two checkpoint generations
    // with retention, the newest one corrupted on disk — serve must boot
    // the predecessor, quarantine the bad copy, and tell the operator
    // which weights are actually live via /stats
    let bpe = build_small_bpe();
    let dir = std::env::temp_dir().join(format!(
        "lram_srv_fallback_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = EngineConfig { torus_k: [4; 8], k_top: 8, ..engine_cfg() };
    let model = LramMlm::seeded(cfg, bpe.vocab_size()).unwrap();
    model.save_checkpoint(&dir, 1, &bpe.fingerprint(), None, None, false, 2).unwrap();
    model.save_checkpoint(&dir, 2, &bpe.fingerprint(), None, None, false, 2).unwrap();
    let prev = dir.with_file_name(format!(
        "{}.prev-1",
        dir.file_name().unwrap().to_str().unwrap()
    ));
    let prev_id = lram::checkpoint::Checkpoint::open(&prev)
        .expect("retention left a verifying predecessor")
        .manifest
        .checkpoint_id;

    // corrupt the live generation's value table (length-preserving byte
    // flip, so it fails the checksum, not the size check)
    let values = dir.join("values.bin");
    let mut bytes = std::fs::read(&values).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&values, &bytes).unwrap();

    let batcher = Batcher::spawn(
        BackendInit::EngineCheckpoint(CheckpointInit::new(dir.to_str().unwrap())),
        bpe.clone(),
        BatcherConfig::default(),
    )
    .expect("serve must recover from a corrupt latest checkpoint");
    assert_eq!(
        batcher.stats.lock().unwrap().checkpoint.as_deref(),
        Some(prev_id.as_str()),
        "the recovered (predecessor) id must be the one reported"
    );
    // the bad copy was preserved for forensics, not deleted
    let name = dir.file_name().unwrap().to_str().unwrap().to_string();
    let quarantined = std::fs::read_dir(dir.parent().unwrap())
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            e.file_name()
                .to_str()
                .is_some_and(|n| n.starts_with(&format!("{name}.quarantine-")))
        })
        .count();
    assert_eq!(quarantined, 1, "exactly one quarantined sibling");

    // requests flow from the recovered weights, and /stats names them
    let server = start_server(batcher, bpe);
    let mut c = Client::connect(&server.local_addr().to_string());
    let resp = c.predict("the [MASK] sat", 2);
    assert_eq!(resp.status, 200, "{}", resp.body);
    let stats = c.get("/stats");
    assert!(
        stats.body.contains(&format!(r#""checkpoint": "{prev_id}""#)),
        "/stats must report the recovered checkpoint: {}",
        stats.body
    );
    server.shutdown();
    // clean up the dir and every sibling this test created
    for e in std::fs::read_dir(dir.parent().unwrap()).unwrap().filter_map(|e| e.ok()) {
        if e.file_name().to_str().is_some_and(|n| n.starts_with(&name)) {
            let _ = std::fs::remove_dir_all(e.path());
        }
    }
}

#[test]
fn slow_client_gets_408_and_does_not_wedge_the_worker_pool() {
    // a client that sends half its body and stalls must be expired with
    // a well-formed 408 within the request deadline — not pin its worker
    // forever — and other clients must be served meanwhile
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let server = start_server_with(
        batcher,
        bpe,
        HttpConfig {
            workers: 2,
            request_deadline: Duration::from_millis(400),
            ..HttpConfig::default()
        },
    );
    let addr = server.local_addr().to_string();

    // wedge attempt: full headers, half the promised body, then silence
    let mut slow = TcpStream::connect(&addr).unwrap();
    slow.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let body = r#"{"text": "the [MASK] sat", "top_k": 2}"#;
    write!(
        slow,
        "POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        &body[..body.len() / 2]
    )
    .unwrap();
    slow.flush().unwrap();

    // while the slow client stalls, the other worker serves normally
    let mut ok = Client::connect(&addr);
    let resp = ok.predict("the [MASK] sat", 2);
    assert_eq!(resp.status, 200, "healthy client starved by a stalled one: {}", resp.body);
    // free the healthy client's keep-alive worker before counting slots
    drop(ok);

    // the stalled request ends in a well-formed 408 + close, not a hang
    let mut raw = String::new();
    slow.read_to_string(&mut raw).expect("server must answer, then close");
    assert!(raw.starts_with("HTTP/1.1 408"), "expected 408, got: {raw}");
    assert!(raw.contains("Connection: close"), "{raw}");
    assert!(raw.contains("timed out"), "{raw}");

    // the wedged slot is free again: two fresh connections are both
    // served concurrently, so the pool is back to full strength
    let mut c1 = Client::connect(&addr);
    let mut c2 = Client::connect(&addr);
    assert_eq!(c1.get("/healthz").status, 200);
    assert_eq!(c2.get("/healthz").status, 200);
    server.shutdown();
}

#[test]
fn readyz_reports_ready_and_stats_carry_health_fields() {
    let bpe = build_small_bpe();
    let batcher = spawn_engine_batcher(bpe.clone());
    let server = start_server(batcher, bpe);
    let mut c = Client::connect(&server.local_addr().to_string());
    let ready = c.get("/readyz");
    assert_eq!(ready.status, 200, "{}", ready.body);
    assert!(ready.body.contains(r#""state": "ready""#), "{}", ready.body);
    let health = c.get("/healthz");
    assert_eq!(health.status, 200);
    assert!(health.body.contains(r#""ok": true"#), "{}", health.body);
    let stats = c.get("/stats");
    let v = lram::util::json::parse(&stats.body).unwrap();
    assert_eq!(v.get("state").unwrap().as_str().unwrap(), "ready");
    assert_eq!(v.get("restarts").unwrap().as_usize().unwrap(), 0);
    assert_eq!(v.get("timeouts").unwrap().as_usize().unwrap(), 0);
    assert_eq!(v.get("worker_panics").unwrap().as_usize().unwrap(), 0);
    server.shutdown();
}

#[test]
fn seed_engine_requires_explicit_random_init_on_the_flag_path() {
    // the spawn_for_flag surface behind `lram serve`: engine without a
    // checkpoint must demand --random-init, and accept it when given
    let bpe = build_small_bpe();
    let artifact = ArtifactInit {
        artifact_dir: "does-not-exist".into(),
        artifact_name: "infer_logits_baseline".into(),
        checkpoint: None,
    };
    let refused = Batcher::spawn_for_flag(
        "engine",
        artifact.clone(),
        engine_cfg(),
        None,
        false,
        bpe.clone(),
        BatcherConfig::default(),
    );
    let err = format!("{:#}", refused.err().expect("seed weights need explicit opt-in"));
    assert!(err.contains("random-init"), "{err}");
    let accepted = Batcher::spawn_for_flag(
        "engine",
        artifact,
        engine_cfg(),
        None,
        true,
        bpe,
        BatcherConfig::default(),
    );
    assert!(accepted.is_ok());
    assert!(accepted.unwrap().stats.lock().unwrap().checkpoint.is_none());
}

// ---------------------------------------------------------------------
// artifact backend: exercises the PJRT path when artifacts exist
// ---------------------------------------------------------------------

#[test]
fn batcher_answers_fill_mask_requests() {
    let dir = require!(artifact_dir());
    let batcher = require!(spawn_artifact_batcher(&dir));
    let bpe = build_bpe();
    let req = PredictRequest { text: "the [MASK] of the".into(), top_k: 5 };
    let resp = batcher.submit(&bpe, &req).unwrap();
    assert_eq!(resp.masks.len(), 1);
    let scores = resp.masks[0].scores().unwrap();
    assert_eq!(scores.len(), 5);
    let lps: Vec<f64> = scores.iter().map(|c| c.logprob).collect();
    for w in lps.windows(2) {
        assert!(w[0] >= w[1]);
    }
    assert!(lps.iter().all(|l| l.is_finite() && *l <= 0.0));
}

#[test]
fn request_without_mask_errors() {
    let dir = require!(artifact_dir());
    let batcher = require!(spawn_artifact_batcher(&dir));
    let bpe = build_bpe();
    let req = PredictRequest { text: "no mask here".into(), top_k: 3 };
    assert!(batcher.submit(&bpe, &req).is_err());
}

#[test]
fn http_end_to_end() {
    let dir = require!(artifact_dir());
    let batcher = require!(spawn_artifact_batcher(&dir));
    let bpe = build_bpe();
    let server = start_server(batcher, bpe);
    let mut c = Client::connect(&server.local_addr().to_string());
    let resp = c.predict("the [MASK] sat", 2);
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert!(resp.body.contains("\"masks\""), "{}", resp.body);

    // health endpoint, same keep-alive socket
    let health = c.get("/healthz");
    assert_eq!(health.status, 200);
    assert!(health.body.contains(r#""ok": true"#), "{}", health.body);
    server.shutdown();
}
