//! Differential contract: the fused SoA [`BatchLookupEngine`] must be
//! indistinguishable from the scalar [`LatticeLookup`] oracle — same
//! torus indices, bit-identical weights (after the engine's f64 -> f32
//! narrowing), same totals — across random queries, batch sizes, torus
//! geometries and thread counts.

use lram::lattice::e8::Vec8;
use lram::lattice::{BatchLookupEngine, BatchOutput, LatticeLookup, TorusK};
use lram::memstore::ValueTable;
use lram::util::check::forall;
use lram::util::rng::Rng;

fn random_torus(rng: &mut Rng) -> TorusK {
    let choices = [
        [16, 16, 8, 8, 8, 8, 8, 8],   // paper LRAM-small (2^18)
        [8, 8, 8, 8, 8, 8, 8, 8],     // uniform 2^16
        [4, 4, 8, 8, 8, 8, 4, 16],    // mixed small periods (with wrap)
        [12, 8, 8, 8, 4, 4, 8, 8],    // non-power-of-two period
    ];
    TorusK::new(choices[rng.below(choices.len() as u64) as usize]).unwrap()
}

#[test]
fn engine_matches_scalar_oracle_across_configs() {
    forall(40, |rng| {
        let torus = random_torus(rng);
        let k_top = [1usize, 4, 16, 32][rng.below(4) as usize];
        let batch = 1 + rng.below(48) as usize;
        let threads = 1 + rng.below(6) as usize;
        let span = 4.0 + rng.uniform(0.0, 20.0);
        let queries: Vec<f64> =
            (0..batch * 8).map(|_| rng.uniform(-span, span)).collect();

        let engine = BatchLookupEngine::with_threads(torus, k_top, threads);
        let out = engine.lookup_batch(&queries);
        assert_eq!(out.queries(), batch);
        assert_eq!(out.k_top(), k_top);

        let mut oracle = LatticeLookup::new(torus, k_top);
        for (qi, chunk) in queries.chunks_exact(8).enumerate() {
            let q: Vec8 = chunk.try_into().unwrap();
            let want = oracle.lookup(&q);
            let (idx, wts) = out.query(qi);
            assert!(
                (out.total_weight[qi] - want.total_weight).abs() < 1e-12,
                "total weight diverged on query {qi}"
            );
            assert!(want.hits.len() <= k_top);
            for (j, hit) in want.hits.iter().enumerate() {
                assert_eq!(idx[j], hit.index, "index diverged: query {qi} hit {j}");
                let narrowed = hit.weight as f32;
                assert!(
                    (wts[j] - narrowed).abs() as f64 <= 1e-12,
                    "weight diverged: query {qi} hit {j}: {} vs {narrowed}",
                    wts[j]
                );
            }
            for j in want.hits.len()..k_top {
                assert_eq!(idx[j], 0, "padding index: query {qi} slot {j}");
                assert_eq!(wts[j], 0.0, "padding weight: query {qi} slot {j}");
            }
        }
    });
}

#[test]
fn thread_sharding_is_invisible() {
    forall(20, |rng| {
        let torus = random_torus(rng);
        let batch = 1 + rng.below(100) as usize;
        let queries: Vec<f64> =
            (0..batch * 8).map(|_| rng.uniform(-10.0, 10.0)).collect();
        let single = BatchLookupEngine::new(torus, 32).lookup_batch(&queries);
        let threads = 2 + rng.below(14) as usize;
        let sharded =
            BatchLookupEngine::with_threads(torus, 32, threads).lookup_batch(&queries);
        assert_eq!(single.indices, sharded.indices);
        assert_eq!(single.weights, sharded.weights);
        assert_eq!(single.total_weight, sharded.total_weight);
    });
}

#[test]
fn fused_gather_matches_scalar_lookup_plus_gather() {
    let torus = TorusK::new([16, 16, 8, 8, 8, 8, 8, 8]).unwrap();
    let mut table = ValueTable::zeros(torus.num_locations(), 32).unwrap();
    table.randomize(0xBEE, 0.02);
    forall(15, |rng| {
        let batch = 1 + rng.below(32) as usize;
        let threads = 1 + rng.below(4) as usize;
        let queries: Vec<f64> =
            (0..batch * 8).map(|_| rng.uniform(-9.0, 9.0)).collect();
        let engine = BatchLookupEngine::with_threads(torus, 32, threads);
        let mut lk = BatchOutput::default();
        let mut fused = vec![0.0f32; batch * 32];
        engine.lookup_gather_into(&queries, &table, &mut lk, &mut fused);

        let mut oracle = LatticeLookup::new(torus, 32);
        let mut expect = vec![0.0f32; 32];
        for (qi, chunk) in queries.chunks_exact(8).enumerate() {
            let q: Vec8 = chunk.try_into().unwrap();
            let r = oracle.lookup(&q);
            let idx: Vec<u64> = r.hits.iter().map(|h| h.index).collect();
            let wts: Vec<f32> = r.hits.iter().map(|h| h.weight as f32).collect();
            table.gather_weighted(&idx, &wts, &mut expect);
            assert_eq!(
                &fused[qi * 32..(qi + 1) * 32],
                &expect[..],
                "fused gather diverged on query {qi}"
            );
        }
    });
}
