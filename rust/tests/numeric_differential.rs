//! Tolerance-based differential contract for the f32 SIMD serving path
//! and the int8-quantized gather: both must track the f64 engine (which
//! `batch_differential.rs` pins bit-exactly to the scalar oracle) within
//! analytically justified bounds — across random torus geometries, batch
//! sizes, thread counts, NaN/denormal queries, empty-support inputs, and
//! ragged final batches.
//!
//! CI runs this binary twice in release: once with the native SIMD
//! dispatch (AVX2/NEON where available) and once with `LRAM_SIMD=off`
//! forcing the scalar f32 kernel, so both sides of the runtime dispatch
//! carry the same contract.

use std::collections::BTreeMap;

use lram::lattice::{simd, BatchLookupEngine, BatchOutput, TorusK};
use lram::memstore::{QuantizedValueTable, ValueTable};
use lram::util::check::forall;
use lram::util::rng::Rng;

fn random_torus(rng: &mut Rng) -> TorusK {
    let choices = [
        [16, 16, 8, 8, 8, 8, 8, 8],   // paper LRAM-small (2^18)
        [8, 8, 8, 8, 8, 8, 8, 8],     // uniform 2^16
        [4, 4, 8, 8, 8, 8, 4, 16],    // mixed small periods (with wrap)
        [12, 8, 8, 8, 4, 4, 8, 8],    // non-power-of-two period
    ];
    TorusK::new(choices[rng.below(choices.len() as u64) as usize]).unwrap()
}

/// `torus row -> weight` for one query, dropping zero-weight padding.
fn by_row(o: &BatchOutput, qi: usize) -> BTreeMap<u64, f32> {
    let (idx, wts) = o.query(qi);
    idx.iter().zip(wts).filter(|&(_, &w)| w > 0.0).map(|(&i, &w)| (i, w)).collect()
}

/// Weights from f32 scoring may differ from f64 by rounding of the
/// quartic kernel, and a candidate sitting within f32 rounding of the
/// d2 = 8 support boundary may appear on one side only — with a weight
/// below this same tolerance.
const W_TOL: f32 = 1e-4;

#[test]
fn f32_weights_track_the_f64_engine_across_configs() {
    forall(30, |rng| {
        let torus = random_torus(rng);
        let batch = 1 + rng.below(48) as usize;
        let threads = 1 + rng.below(6) as usize;
        let span = 4.0 + rng.uniform(0.0, 20.0);
        let queries: Vec<f64> = (0..batch * 8).map(|_| rng.uniform(-span, span)).collect();

        // k_top = 232 keeps every in-support candidate on both paths, so
        // the row sets can only differ at the support boundary
        let engine = BatchLookupEngine::with_threads(torus, 232, threads);
        let base = engine.lookup_batch(&queries);
        let fast = engine.lookup_batch_f32(&queries);
        for qi in 0..batch {
            assert!(
                (fast.total_weight[qi] - base.total_weight[qi]).abs() < W_TOL as f64,
                "query {qi}: f32 total {} vs f64 total {}",
                fast.total_weight[qi],
                base.total_weight[qi]
            );
            let b = by_row(&base, qi);
            let f = by_row(&fast, qi);
            for (row, &w) in &b {
                let fw = f.get(row).copied().unwrap_or(0.0);
                assert!((w - fw).abs() < W_TOL, "query {qi} row {row}: f64 {w} vs f32 {fw}");
            }
            for (row, &w) in &f {
                let bw = b.get(row).copied().unwrap_or(0.0);
                assert!((w - bw).abs() < W_TOL, "query {qi} row {row}: f32 {w} vs f64 {bw}");
            }
        }
    });
}

#[test]
fn f32_truncated_top_k_agrees_where_untied() {
    // with a small k_top the two paths must keep the same rows whenever
    // the weight at the cut is not within f32 rounding of its neighbours
    forall(20, |rng| {
        let torus = random_torus(rng);
        let k_top = [4usize, 8, 32][rng.below(3) as usize];
        let batch = 1 + rng.below(32) as usize;
        let queries: Vec<f64> =
            (0..batch * 8).map(|_| rng.uniform(-10.0, 10.0)).collect();
        let engine = BatchLookupEngine::new(torus, k_top);
        let base = engine.lookup_batch(&queries);
        let fast = engine.lookup_batch_f32(&queries);
        for qi in 0..batch {
            let b = by_row(&base, qi);
            let f = by_row(&fast, qi);
            // membership may differ only at the selection cut: a row one
            // path kept and the other dropped must weigh within f32
            // rounding of the lightest row the other path kept instead
            let bmin = b.values().copied().fold(f32::INFINITY, f32::min);
            let fmin = f.values().copied().fold(f32::INFINITY, f32::min);
            for (row, &w) in &b {
                match f.get(row) {
                    Some(&fw) => assert!(
                        (w - fw).abs() < W_TOL,
                        "query {qi} row {row}: f64 {w} vs f32 {fw}"
                    ),
                    None => assert!(
                        (w - fmin).abs() < W_TOL,
                        "query {qi} row {row}: f64 kept weight {w} but the f32 \
                         cut was at {fmin}"
                    ),
                }
            }
            for (row, &w) in &f {
                if !b.contains_key(row) {
                    assert!(
                        (w - bmin).abs() < W_TOL,
                        "query {qi} row {row}: f32 kept weight {w} but the f64 \
                         cut was at {bmin}"
                    );
                }
            }
        }
    });
}

#[test]
fn fused_f32_and_q8_gathers_track_the_f64_gather() {
    forall(12, |rng| {
        let torus = random_torus(rng);
        let m = [8usize, 16, 64][rng.below(3) as usize];
        let mut table = ValueTable::zeros(torus.num_locations(), m).unwrap();
        table.randomize(rng.below(1 << 30), 0.02);
        let qtab = QuantizedValueTable::from_table(&table).unwrap();
        let batch = 1 + rng.below(24) as usize;
        let threads = 1 + rng.below(4) as usize;
        let queries: Vec<f64> = (0..batch * 8).map(|_| rng.uniform(-9.0, 9.0)).collect();
        let engine = BatchLookupEngine::with_threads(torus, 232, threads);

        let mut lk64 = BatchOutput::default();
        let mut g64 = vec![0.0f32; batch * m];
        engine.lookup_gather_ragged_into(&queries, &table, &mut lk64, &mut g64);
        let mut lk32 = BatchOutput::default();
        let mut g32 = vec![0.0f32; batch * m];
        engine.lookup_gather_ragged_f32_into(&queries, &table, &mut lk32, &mut g32);
        let mut lkq8 = BatchOutput::default();
        let mut gq8 = vec![0.0f32; batch * m];
        engine.lookup_gather_ragged_q8_into(&queries, &qtab, &mut lkq8, &mut gq8);

        // values ~N(0, 0.02) and weights summing below 1: f32 scoring
        // perturbs each element by < W_TOL * max|v|, and quantisation
        // adds < sum_j w_j * scale_j / 2 — both comfortably inside 2e-3
        for i in 0..batch * m {
            assert!(
                (g64[i] - g32[i]).abs() < 2e-3,
                "elem {i}: f64 gather {} vs f32 gather {}",
                g64[i],
                g32[i]
            );
            assert!(
                (g64[i] - gq8[i]).abs() < 2e-3,
                "elem {i}: f64 gather {} vs q8 gather {}",
                g64[i],
                gq8[i]
            );
        }
    });
}

#[test]
fn nan_denormal_and_infinite_queries_degrade_like_the_f64_engine() {
    let torus = TorusK::new([16, 16, 8, 8, 8, 8, 8, 8]).unwrap();
    let engine = BatchLookupEngine::new(torus, 32);
    // query 0: NaN component; query 1: all denormals (narrow to 0.0f32);
    // query 2: +inf component (empty-support cell); query 3: clean
    let mut queries = vec![0.25f64; 4 * 8];
    queries[3] = f64::NAN;
    for v in queries.iter_mut().take(16).skip(8) {
        *v = 4.9e-324; // smallest positive subnormal f64
    }
    queries[2 * 8 + 5] = f64::INFINITY;
    let base = engine.lookup_batch(&queries);
    let fast = engine.lookup_batch_f32(&queries);
    for (qi, label) in [(0usize, "NaN"), (2, "+inf")] {
        for out in [&base, &fast] {
            let (idx, wts) = out.query(qi);
            assert!(idx.iter().all(|&i| i == 0), "{label} query {qi} must have no hits");
            assert!(wts.iter().all(|&w| w == 0.0), "{label} query {qi} must have no hits");
            assert_eq!(out.total_weight[qi], 0.0, "{label} query {qi}");
        }
    }
    // denormals behave exactly like the origin query on both paths
    for (qi, label) in [(1usize, "denormal"), (3, "clean")] {
        assert!(base.total_weight[qi] > 0.0, "{label} query lives");
        assert!(
            (base.total_weight[qi] - fast.total_weight[qi]).abs() < W_TOL as f64,
            "{label} query {qi}: totals diverged"
        );
        let b = by_row(&base, qi);
        let f = by_row(&fast, qi);
        for (row, &w) in &b {
            let fw = f.get(row).copied().unwrap_or(0.0);
            assert!((w - fw).abs() < W_TOL, "{label} query {qi} row {row}");
        }
    }
}

#[test]
fn ragged_final_batches_reuse_oversized_buffers_cleanly() {
    // serving reuses one gather buffer sized for max_batch; a short final
    // batch must only write its N * m prefix and match a tight-buffer run
    let torus = TorusK::new([8; 8]).unwrap();
    let m = 16usize;
    let mut table = ValueTable::zeros(torus.num_locations(), m).unwrap();
    table.randomize(77, 0.02);
    let engine = BatchLookupEngine::new(torus, 32);
    let mut rng = Rng::new(123);
    let full: Vec<f64> = (0..32 * 8).map(|_| rng.uniform(-8.0, 8.0)).collect();
    let mut lk = BatchOutput::default();
    let mut big = vec![f32::NAN; 32 * m];
    engine.lookup_gather_ragged_f32_into(&full, &table, &mut lk, &mut big);
    assert!(big.iter().all(|v| v.is_finite()), "full batch fills the buffer");
    for short in [1usize, 5, 31] {
        let mut ragged = vec![f32::NAN; 32 * m];
        let mut lk2 = BatchOutput::default();
        engine.lookup_gather_ragged_f32_into(
            &full[..short * 8],
            &table,
            &mut lk2,
            &mut ragged,
        );
        assert_eq!(lk2.queries(), short);
        assert_eq!(&ragged[..short * m], &big[..short * m], "prefix b={short}");
        assert!(
            ragged[short * m..].iter().all(|v| v.is_nan()),
            "b={short}: bytes past N * m must stay untouched"
        );
    }
}

#[test]
fn dispatch_honours_the_simd_kill_switch() {
    let name = simd::active_kernel_name();
    if std::env::var("LRAM_SIMD").as_deref() == Ok("off") {
        assert_eq!(name, "scalar-f32", "LRAM_SIMD=off must force the scalar kernel");
    } else {
        // whatever was picked, it must be a known kernel
        assert!(
            ["scalar-f32", "avx2+fma", "neon"].contains(&name),
            "unknown kernel {name}"
        );
    }
}
