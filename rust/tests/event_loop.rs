//! Event-driven front-door behavior that the worker-pool tests never
//! pinned down: connection-level shedding handled *off* the acceptor
//! thread, the `active_connections` gauge returning to zero through
//! panic teardown, slow-loris expiry while the request line is still
//! incomplete, the silent idle keep-alive sweep, and pipelined
//! requests on one socket.
//!
//! Everything here runs on the engine backend (no artifacts, no PJRT).
//! The failpoint registry is process-global, so every test takes the
//! same gate mutex chaos.rs uses — serialized, never flaky.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use lram::data::synth::CorpusSpec;
use lram::data::DataPipeline;
use lram::server::{BackendInit, Batcher, BatcherConfig, EngineConfig, HttpConfig, Server};
use lram::util::failpoint;

/// Failpoints are process-global: serialize the whole binary so an
/// armed site can never leak into a neighboring test's requests.
static GATE: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    let g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    failpoint::clear_all();
    g
}

fn build_small_bpe() -> Arc<lram::tokenizer::Bpe> {
    let p = DataPipeline::new(CorpusSpec::default(), 512, 8, 1, 0.15).unwrap();
    Arc::new(p.bpe)
}

fn engine_cfg() -> EngineConfig {
    EngineConfig { max_batch: 4, seq_len: 24, width: 32, m: 32, ..EngineConfig::default() }
}

fn start_server(cfg: HttpConfig) -> Server {
    let bpe = build_small_bpe();
    let batcher = Batcher::spawn(BackendInit::Engine(engine_cfg()), bpe.clone(), BatcherConfig::default())
        .expect("engine backend needs no artifacts");
    Server::bind("127.0.0.1:0", batcher, bpe, cfg).expect("binding an ephemeral port")
}

/// A persistent client connection: write half + buffered read half.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

struct Resp {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Resp {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).expect("connecting to test server");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn send(&mut self, raw: &str) {
        self.stream.write_all(raw.as_bytes()).expect("writing request");
    }

    /// Read exactly one response off the buffered reader.
    fn read_response(&mut self) -> Resp {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("reading status line");
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .unwrap_or_else(|| panic!("bad status line {line:?}"))
            .parse()
            .expect("numeric status");
        let mut headers = Vec::new();
        loop {
            let mut h = String::new();
            self.reader.read_line(&mut h).expect("reading header");
            let h = h.trim();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
            }
        }
        let len: usize = headers
            .iter()
            .find(|(k, _)| k == "content-length")
            .map(|(_, v)| v.parse().expect("numeric content-length"))
            .expect("response carries Content-Length");
        let mut body = vec![0u8; len];
        self.reader.read_exact(&mut body).expect("reading body");
        Resp { status, headers, body: String::from_utf8(body).expect("utf-8 body") }
    }

    fn roundtrip(&mut self, raw: &str) -> Resp {
        self.send(raw);
        self.read_response()
    }

    fn predict(&mut self, text: &str, top_k: usize) -> Resp {
        let body = format!(r#"{{"text": "{text}", "top_k": {top_k}}}"#);
        self.roundtrip(&format!(
            "POST /predict HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ))
    }

    fn get(&mut self, path: &str) -> Resp {
        self.roundtrip(&format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
    }
}

/// Poll an HTTP gauge until it reaches `want` (bounded, not a sleep).
fn await_gauge(read: impl Fn() -> usize, want: usize, what: &str) {
    let t0 = Instant::now();
    while read() != want {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "{what} stuck at {} (want {want})",
            read()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn pipelined_requests_on_one_socket_are_each_answered() {
    let _g = guard();
    let server = start_server(HttpConfig::default());
    let mut c = Client::connect(&server.local_addr().to_string());
    // both requests land in one TCP segment; the loop must answer the
    // first, then parse the second out of the residual buffer without
    // waiting for more readable bytes
    c.send("GET /healthz HTTP/1.1\r\nHost: t\r\n\r\nGET /readyz HTTP/1.1\r\nHost: t\r\n\r\n");
    let first = c.read_response();
    assert_eq!(first.status, 200, "{}", first.body);
    assert!(first.body.contains(r#""ok": true"#), "{}", first.body);
    let second = c.read_response();
    assert_eq!(second.status, 200, "{}", second.body);
    assert!(second.body.contains(r#""state""#), "{}", second.body);
    assert_eq!(
        server.http_stats().connections_accepted.load(Ordering::Relaxed),
        1,
        "both requests on the same connection"
    );
    server.shutdown();
}

#[test]
fn connection_shed_is_written_by_the_event_loop_not_the_acceptor() {
    let _g = guard();
    // one admitted connection fills the house; every later connect must
    // shed with a polite 429 — written by an event loop, so shed peers
    // that never read cannot stall the accept path
    let server = start_server(HttpConfig {
        workers: 2,
        max_connections: 1,
        ..HttpConfig::default()
    });
    let addr = server.local_addr().to_string();
    let http = server.http_stats();

    let mut admitted = Client::connect(&addr);
    let resp = admitted.predict("the [MASK] sat", 2);
    assert_eq!(resp.status, 200, "{}", resp.body);

    // four peers that connect and then neither write nor read: the old
    // front door answered sheds synchronously from the acceptor thread,
    // where one bad peer stalled all accepts behind it
    const SHED: usize = 4;
    let mut parked: Vec<TcpStream> = (0..SHED)
        .map(|_| {
            let s = TcpStream::connect(&addr).expect("connect for shedding");
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            s
        })
        .collect();
    await_gauge(
        || http.connections_shed.load(Ordering::Relaxed) as usize,
        SHED,
        "connections_shed",
    );

    // with all four shed peers still parked unread, the admitted
    // connection is served as if nothing happened
    let resp = admitted.predict("round two [MASK] .", 2);
    assert_eq!(resp.status, 200, "admitted client starved by parked shed peers: {}", resp.body);

    // each shed peer holds a complete, well-formed 429 + close
    for (i, s) in parked.iter_mut().enumerate() {
        let mut raw = String::new();
        s.read_to_string(&mut raw).expect("shed response then close");
        assert!(raw.starts_with("HTTP/1.1 429"), "peer {i}: {raw}");
        assert!(raw.contains("Connection: close"), "peer {i}: {raw}");
        assert!(raw.contains("Retry-After:"), "peer {i}: {raw}");
        let body = raw.split("\r\n\r\n").nth(1).expect("429 carries a body");
        let v = lram::util::json::parse(body).expect("429 body is JSON");
        let err = v.get("error").expect("structured error envelope");
        assert_eq!(err.get("code").unwrap().as_str().unwrap(), "overloaded", "peer {i}");
    }
    drop(parked);

    // the slot frees when the admitted connection goes away, and a new
    // client is admitted again — the gauge did not drift
    drop(admitted);
    await_gauge(
        || http.active_connections.load(Ordering::Relaxed),
        0,
        "active_connections",
    );
    let mut fresh = Client::connect(&addr);
    assert_eq!(fresh.get("/healthz").status, 200);
    server.shutdown();
}

#[test]
fn active_connections_returns_to_zero_through_panic_teardown() {
    let _g = guard();
    let server = start_server(HttpConfig { workers: 2, ..HttpConfig::default() });
    let addr = server.local_addr().to_string();
    let http = server.http_stats();

    // two connections, each of whose single request panics the handler:
    // both must get a well-formed 503 + close, and both teardowns must
    // release their admission slot
    failpoint::set("http.worker", "panic:1.0:2").unwrap();
    for i in 0..2 {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(s, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).expect("panic must still answer, then close");
        assert!(raw.starts_with("HTTP/1.1 503"), "conn {i}: {raw}");
        assert!(raw.contains("Connection: close"), "conn {i}: {raw}");
        assert!(raw.contains("panicked"), "conn {i}: {raw}");
    }
    failpoint::clear_all();

    assert_eq!(http.worker_panics.load(Ordering::Relaxed), 2);
    await_gauge(
        || http.active_connections.load(Ordering::Relaxed),
        0,
        "active_connections",
    );

    // the loops survived: a fresh connection is served normally
    let mut c = Client::connect(&addr);
    assert_eq!(c.get("/healthz").status, 200);
    server.shutdown();
}

#[test]
fn slow_loris_request_line_is_expired_with_408() {
    let _g = guard();
    // the pre-body loris: a partial request *line* and then silence.
    // The head deadline arms on the first byte, so the connection is
    // expired with a 408 — it does not ride the (longer) idle timeout,
    // and it does not hold its event loop
    let server = start_server(HttpConfig {
        request_deadline: Duration::from_millis(300),
        ..HttpConfig::default()
    });
    let addr = server.local_addr().to_string();

    let mut loris = TcpStream::connect(&addr).unwrap();
    loris.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(loris, "GET /hea").unwrap();
    loris.flush().unwrap();
    let t0 = Instant::now();

    // meanwhile the loop keeps serving others
    let mut ok = Client::connect(&addr);
    assert_eq!(ok.get("/healthz").status, 200);

    let mut raw = String::new();
    loris.read_to_string(&mut raw).expect("server must answer, then close");
    assert!(raw.starts_with("HTTP/1.1 408"), "expected 408, got: {raw}");
    assert!(raw.contains("Connection: close"), "{raw}");
    assert!(raw.contains("timed out"), "{raw}");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "408 took {:?}, deadline was 300ms",
        t0.elapsed()
    );
    server.shutdown();
}

#[test]
fn idle_keep_alive_connections_are_swept_silently() {
    let _g = guard();
    let server = start_server(HttpConfig {
        keep_alive_timeout: Duration::from_millis(200),
        ..HttpConfig::default()
    });
    let addr = server.local_addr().to_string();
    let http = server.http_stats();

    // a connection that never sends a byte is closed silently — EOF,
    // not a 408 (nothing was in flight to time out)
    let mut idle = TcpStream::connect(&addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut raw = String::new();
    idle.read_to_string(&mut raw).expect("sweep closes cleanly");
    assert!(raw.is_empty(), "idle sweep must not write anything: {raw}");

    // a connection that finished a request and then idles gets the same
    // silent sweep after its response
    let mut c = Client::connect(&addr);
    assert_eq!(c.get("/healthz").status, 200);
    let mut rest = String::new();
    c.reader.read_to_string(&mut rest).expect("sweep closes cleanly");
    assert!(rest.is_empty(), "post-response sweep must not write anything: {rest}");

    await_gauge(
        || http.active_connections.load(Ordering::Relaxed),
        0,
        "active_connections",
    );
    server.shutdown();
}
