//! The checkpoint subsystem's end-to-end contract, locked in as a test
//! harness: **train → save → serve trained weights, artifact-free, with
//! bit-identical logits**.
//!
//! A tiny synthetic MLM is trained for a few steps with the pure-rust
//! [`EngineTrainer`], checkpointed, restored into the serving
//! [`EngineBackend`], and the served `/fill-mask` scores are compared
//! bit-for-bit (f32 logits and the f64 log-probs that cross the HTTP
//! JSON boundary) against the trainer's own forward pass.  Negative
//! tests pin down the failure discipline: corruption, truncation and
//! version skew all refuse to load with explicit errors.
//!
//! Everything here runs everywhere — no artifacts, no PJRT.
//!
//! Set `LRAM_CKPT_OUT=<dir>` to keep the trained tiny checkpoint (CI
//! uploads it as a build artifact so regressions are reproducible).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use lram::checkpoint::{Checkpoint, MANIFEST_FILE};
use lram::coordinator::{EngineTrainConfig, EngineTrainer};
use lram::data::mlm::fit_length;
use lram::model::EngineConfig;
use lram::server::batcher::encode_with_masks;
use lram::server::{
    BackendInit, Batcher, BatcherConfig, CheckpointInit, EngineBackend, HttpConfig,
    InferenceBackend, PredictRequest, Server,
};
use lram::util::json;

fn tiny_model() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        seq_len: 16,
        width: 16,
        heads: 2,
        m: 8,
        k_top: 8,
        torus_k: [4; 8], // 256 memory slots: milliseconds, same structure
        threads: 1,
        ..EngineConfig::default()
    }
}

fn tiny_train_cfg() -> EngineTrainConfig {
    EngineTrainConfig {
        model: tiny_model(),
        steps: 12,
        batch: 4,
        vocab_size: 512,
        ..EngineTrainConfig::default()
    }
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "lram_ckpt_rt_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
}

fn assert_bits_equal(a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "logit count mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "logit {i}: {x} vs {y}");
    }
}

/// Rewrite the manifest's `version` field in place (skew simulations;
/// the blob layout of versions 1 and 2 is identical, so a version-1
/// fixture is exactly a version-2 checkpoint minus the routing tensors).
fn patch_manifest_version(dir: &Path, to: i64) {
    let path = dir.join(MANIFEST_FILE);
    let text = std::fs::read_to_string(&path).unwrap();
    let from = format!("\"version\":{}", lram::checkpoint::FORMAT_VERSION);
    assert!(text.contains(&from), "manifest must carry the current format version");
    std::fs::write(&path, text.replace(&from, &format!("\"version\":{to}"))).unwrap();
}

/// Train a tiny model for a few steps and save it; returns the trainer
/// (for reference forward passes) and the checkpoint directory.
fn train_and_save(tag: &str, steps: u64) -> (EngineTrainer, PathBuf) {
    let mut trainer = EngineTrainer::new(tiny_train_cfg()).unwrap();
    let mut losses = Vec::with_capacity(steps as usize);
    for i in 0..steps {
        let loss = trainer.train_step().unwrap();
        assert!(loss.is_finite(), "step {i}: loss {loss}");
        losses.push(loss);
    }
    if steps >= 10 {
        // the model must actually be learning (averaged over 3 steps so
        // a single noisy batch can't mask steady descent)
        let head: f64 = losses[..3].iter().sum::<f64>() / 3.0;
        let tail: f64 = losses[losses.len() - 3..].iter().sum::<f64>() / 3.0;
        assert!(tail < head, "training went nowhere: first~{head:.4}, last~{tail:.4}");
    }
    let dir = tmp(tag);
    let manifest = trainer.save_checkpoint(&dir).unwrap();
    assert_eq!(manifest.step, steps);
    assert!(manifest.checkpoint_id.starts_with("ck-"));
    (trainer, dir)
}

// ---------------------------------------------------------------------
// the headline: train → save → serve, bit-identical
// ---------------------------------------------------------------------

#[test]
fn trained_logits_served_from_checkpoint_are_bit_identical() {
    let (mut trainer, dir) = train_and_save("headline", 12);

    // the trainer's own (serving-identical, fused-engine) forward pass
    let tokens = trainer.pipeline().val_batch(0).tokens;
    let want = trainer.forward(&tokens).unwrap();

    // restore into the serving backend and infer the same batch
    let bpe = trainer.pipeline().bpe.clone();
    let mut backend =
        EngineBackend::from_checkpoint(&CheckpointInit::new(dir.to_str().unwrap()), &bpe).unwrap();
    assert_eq!(backend.seq_len(), 16);
    let got = backend.infer(&tokens).unwrap();
    assert_bits_equal(&want, &got);

    // a ragged single row must match too (serving never pads)
    let row = &tokens[..16];
    let want_row = trainer.forward(row).unwrap();
    let got_row = backend.infer(row).unwrap();
    assert_bits_equal(&want_row, &got_row);

    // optionally keep the trained checkpoint (CI uploads it)
    match std::env::var_os("LRAM_CKPT_OUT") {
        Some(out) => {
            copy_dir(&dir, Path::new(&out));
            std::fs::remove_dir_all(&dir).ok();
        }
        None => {
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

#[test]
fn served_fill_mask_response_matches_trainer_end_to_end() {
    let (mut trainer, dir) = train_and_save("fillmask", 10);
    let bpe = Arc::new(trainer.pipeline().bpe.clone());

    // full serving path: batcher over the checkpoint-restored backend
    let batcher = Batcher::spawn(
        BackendInit::EngineCheckpoint(CheckpointInit::new(dir.to_str().unwrap())),
        bpe.clone(),
        BatcherConfig::default(),
    )
    .expect("checkpoint backend must start (hash and config match by construction)");

    let text = "the [MASK] of the";
    let top_k = 3usize;
    let resp = batcher.submit(&bpe, &PredictRequest { text: text.into(), top_k }).unwrap();
    assert_eq!(resp.masks.len(), 1);
    let served = resp.masks[0].scores().expect("in-range mask is predicted");
    assert_eq!(served.len(), top_k);

    // reference: the trainer runs the exact request row itself
    let (ids, mask_positions) = encode_with_masks(&bpe, text);
    let row = fit_length(ids, 16);
    let logp = trainer.forward(&row).unwrap();
    let vocab = bpe.vocab_size();
    let pos = mask_positions[0];
    let scores = &logp[pos * vocab..(pos + 1) * vocab];
    let want: Vec<(String, f64)> = lram::util::topk::top_k_indices_f32(scores, top_k)
        .into_iter()
        .map(|i| (bpe.vocab.token(i as i32).to_string(), scores[i] as f64))
        .collect();
    for (s, (token, logprob)) in served.iter().zip(&want) {
        assert_eq!(&s.token, token, "served a different candidate token");
        assert_eq!(
            s.logprob.to_bits(),
            logprob.to_bits(),
            "served log-prob drifted: {} vs {}",
            s.logprob,
            logprob
        );
    }

    // ... and once more over a real socket: the /fill-mask HTTP response
    // (ephemeral port; Connection: close so read_to_string terminates)
    let server = Server::bind("127.0.0.1:0", batcher.clone(), bpe.clone(), HttpConfig::default())
        .expect("binding an ephemeral port");
    let addr = server.local_addr().to_string();
    let mut stream = TcpStream::connect(&addr).expect("connecting to test server");
    let body = format!(r#"{{"text": "{text}", "top_k": {top_k}}}"#);
    write!(
        stream,
        "POST /predict HTTP/1.1\r\nHost: x\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut http_resp = String::new();
    stream.read_to_string(&mut http_resp).unwrap();
    assert!(http_resp.starts_with("HTTP/1.1 200"), "{http_resp}");
    let payload = json::parse(http_resp.lines().last().unwrap()).unwrap();
    let got = payload.get("masks").unwrap().as_arr().unwrap()[0]
        .get("scores")
        .unwrap()
        .as_arr()
        .unwrap();
    assert_eq!(got.len(), top_k);
    for (g, (token, logprob)) in got.iter().zip(&want) {
        assert_eq!(g.get("token").unwrap().as_str().unwrap(), token);
        // f64 survives the JSON round-trip bit-exactly (shortest-repr)
        let served_lp = g.get("logprob").unwrap().as_f64().unwrap();
        assert_eq!(
            served_lp.to_bits(),
            logprob.to_bits(),
            "HTTP log-prob drifted: {served_lp} vs {logprob}"
        );
    }
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// optimizer state: resume == uninterrupted
// ---------------------------------------------------------------------

#[test]
fn resumed_training_is_bit_identical_to_uninterrupted() {
    // A trains 6 steps and checkpoints (weights + sparse-Adam state);
    // B resumes from the checkpoint; both train 4 more steps — every
    // loss and the final logits must agree bit-for-bit, or optimizer
    // state is not really round-tripping
    let (mut a, dir) = train_and_save("resume", 6);
    let mut b = EngineTrainer::from_checkpoint(tiny_train_cfg(), &dir).unwrap();
    assert_eq!(b.step_count(), 6);
    for step in 0..4 {
        let la = a.train_step().unwrap();
        let lb = b.train_step().unwrap();
        assert_eq!(la.to_bits(), lb.to_bits(), "step {step}: loss {la} vs {lb}");
    }
    let tokens = a.pipeline().val_batch(1).tokens;
    let fa = a.forward(&tokens).unwrap();
    let fb = b.forward(&tokens).unwrap();
    assert_bits_equal(&fa, &fb);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// failure discipline: corruption / truncation / version skew
// ---------------------------------------------------------------------

#[test]
fn corrupt_truncated_and_skewed_checkpoints_fail_loudly() {
    let (trainer, dir) = train_and_save("negative", 4);
    let bpe = trainer.pipeline().bpe.clone();
    let open = |d: &Path| {
        EngineBackend::from_checkpoint(&CheckpointInit::new(d.to_str().unwrap()), &bpe)
    };

    // pristine copy loads fine
    let good = tmp("negative_good");
    copy_dir(&dir, &good);
    assert!(open(&good).is_ok());

    // corruption: flip one byte of the embedding blob
    let corrupt = tmp("negative_corrupt");
    copy_dir(&dir, &corrupt);
    let path = corrupt.join("embed.bin");
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", open(&corrupt).unwrap_err());
    assert!(err.contains("checksum"), "corruption must name the checksum: {err}");

    // truncation: chop the tail off the value table
    let trunc = tmp("negative_trunc");
    copy_dir(&dir, &trunc);
    let path = trunc.join("values.bin");
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
    let err = format!("{:#}", open(&trunc).unwrap_err());
    assert!(err.contains("truncated"), "truncation must be explicit: {err}");

    // version skew: a future format version must refuse, not guess
    let skew = tmp("negative_skew");
    copy_dir(&dir, &skew);
    patch_manifest_version(&skew, lram::checkpoint::FORMAT_VERSION + 1);
    let err = format!("{:#}", open(&skew).unwrap_err());
    let vtag = format!("version {}", lram::checkpoint::FORMAT_VERSION + 1);
    assert!(err.contains(&vtag) && err.contains("not supported"), "{err}");

    for d in [&dir, &good, &corrupt, &trunc, &skew] {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn inspect_surface_reads_what_was_saved() {
    // the `lram checkpoint inspect` code path: open + verify + geometry
    let (trainer, dir) = train_and_save("inspect", 4);
    let ck = Checkpoint::open(&dir).unwrap();
    ck.verify().unwrap(); // full checksums, including the value table
    let m = &ck.manifest;
    assert_eq!(m.step, 4);
    assert_eq!(m.model.width, 16);
    assert_eq!(m.model.torus_k, [4; 8]);
    assert_eq!(m.tokenizer_hash, trainer.pipeline().bpe.fingerprint());
    // model weights + value-table optimizer + routing optimizer tensors
    // (routing is trained by default, so its dense-Adam slot rides along)
    for name in [
        "embed", "pos", "wq", "wo", "w_out", "values", "adam_m", "adam_v", "adam_t",
        "wq_adam_m", "wq_adam_v", "wq_adam_t",
    ] {
        assert!(m.has_tensor(name), "missing tensor {name}");
    }
    assert_eq!(m.version, lram::checkpoint::FORMAT_VERSION);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// version skew, both directions (the routing bump is format 1 → 2)
// ---------------------------------------------------------------------

#[test]
fn version1_checkpoint_loads_with_a_fresh_routing_slot() {
    // a PR-3-era checkpoint: same blob layout, version 1, no routing
    // tensors.  Manufacture one by training with --freeze-routing (no
    // wq_adam_* saved) and rewriting the version field.
    let cfg = EngineTrainConfig { train_routing: false, ..tiny_train_cfg() };
    let mut frozen = EngineTrainer::new(cfg.clone()).unwrap();
    for _ in 0..4 {
        frozen.train_step().unwrap();
    }
    let dir = tmp("v1_fixture");
    let manifest = frozen.save_checkpoint(&dir).unwrap();
    assert!(
        !manifest.has_tensor("wq_adam_m"),
        "frozen-routing checkpoints must not carry routing state"
    );
    patch_manifest_version(&dir, 1);

    // the new reader loads it for *serving*...
    let bpe = frozen.pipeline().bpe.clone();
    let mut backend =
        EngineBackend::from_checkpoint(&CheckpointInit::new(dir.to_str().unwrap()), &bpe)
            .expect("version-1 checkpoints must keep serving");
    let tokens = frozen.pipeline().val_batch(0).tokens;
    assert_bits_equal(
        &frozen.forward(&tokens).unwrap(),
        &backend.infer(&tokens).unwrap(),
    );

    // ...and for *resuming with routing on*: absent state → fresh slot,
    // training proceeds and the next save carries the routing tensors
    let mut resumed = EngineTrainer::from_checkpoint(tiny_train_cfg(), &dir).unwrap();
    assert_eq!(resumed.step_count(), 4);
    let loss = resumed.train_step().unwrap();
    assert!(loss.is_finite(), "resumed step diverged: {loss}");
    let dir2 = tmp("v1_upgraded");
    let upgraded = resumed.save_checkpoint(&dir2).unwrap();
    assert_eq!(upgraded.version, lram::checkpoint::FORMAT_VERSION);
    assert!(upgraded.has_tensor("wq_adam_m"), "routing slot must be saved once live");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

#[test]
fn future_version_checkpoint_is_refused_with_upgrade_guidance() {
    // the other direction: this reader meeting a version written by a
    // newer lram must refuse with a message that names the versions it
    // *can* read and points at the fix — from both entry points
    let (trainer, dir) = train_and_save("future_skew", 4);
    patch_manifest_version(&dir, lram::checkpoint::FORMAT_VERSION + 1);
    let bpe = trainer.pipeline().bpe.clone();
    let serve_err = format!(
        "{:#}",
        EngineBackend::from_checkpoint(&CheckpointInit::new(dir.to_str().unwrap()), &bpe)
            .unwrap_err()
    );
    let resume_err = format!(
        "{:#}",
        EngineTrainer::from_checkpoint(tiny_train_cfg(), &dir).unwrap_err()
    );
    for err in [&serve_err, &resume_err] {
        assert!(
            err.contains(&format!("version {}", lram::checkpoint::FORMAT_VERSION + 1)),
            "{err}"
        );
        assert!(err.contains("not supported"), "{err}");
        assert!(
            err.contains(&format!("through {}", lram::checkpoint::FORMAT_VERSION)),
            "the refusal must name the supported range: {err}"
        );
        assert!(err.contains("upgrade"), "the refusal must point at the fix: {err}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// routing-trained checkpoints: save → resume → serve
// ---------------------------------------------------------------------

#[test]
fn routing_trained_checkpoint_roundtrips_save_resume_serve() {
    // train_and_save trains with routing on (the default); the resumed
    // trainer must restore the dense-Adam routing slot bit-identically
    // (divergence would show up as differing losses), and the serving
    // backend must reproduce the trained-wq logits exactly
    let (mut a, dir) = train_and_save("routing_rt", 8);
    let mut b = EngineTrainer::from_checkpoint(tiny_train_cfg(), &dir).unwrap();
    for step in 0..4 {
        let la = a.train_step().unwrap();
        let lb = b.train_step().unwrap();
        assert_eq!(
            la.to_bits(),
            lb.to_bits(),
            "step {step}: routing state did not round-trip ({la} vs {lb})"
        );
    }
    // the trained wq really moved off its seed (routing learned), and
    // serving reproduces it bit-for-bit
    let seeded = lram::model::LramMlm::seeded(tiny_model(), a.model.vocab).unwrap();
    assert_ne!(seeded.wq, a.model.wq, "routing training must move wq");
    let bpe = a.pipeline().bpe.clone();
    let mut backend =
        EngineBackend::from_checkpoint(&CheckpointInit::new(dir.to_str().unwrap()), &bpe)
            .unwrap();
    let tokens = a.pipeline().val_batch(2).tokens;
    // `a` has trained past the checkpoint; serve against a fresh restore
    let mut at_save = EngineTrainer::from_checkpoint(tiny_train_cfg(), &dir).unwrap();
    assert_bits_equal(
        &at_save.forward(&tokens).unwrap(),
        &backend.infer(&tokens).unwrap(),
    );
    std::fs::remove_dir_all(&dir).ok();
}
