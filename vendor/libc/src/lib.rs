//! Offline drop-in subset of the `libc` crate: exactly the FFI surface
//! `util::mmap` (anonymous/file mappings plus `mincore` residency
//! queries) and `util::signal` (`sigaction` for SIGTERM-driven graceful
//! drain) need on 64-bit Linux.  Declaring the prototypes locally links
//! against the system libc that std already pulls in; no crates.io
//! access is required.

#![allow(non_camel_case_types)]

pub use std::ffi::c_void;

pub type c_int = i32;
pub type c_char = i8;
pub type c_uchar = u8;
pub type size_t = usize;
pub type off_t = i64;

pub const PROT_READ: c_int = 0x1;
pub const PROT_WRITE: c_int = 0x2;

pub const MAP_SHARED: c_int = 0x01;
pub const MAP_PRIVATE: c_int = 0x02;
pub const MAP_ANONYMOUS: c_int = 0x20;
pub const MAP_NORESERVE: c_int = 0x4000;

pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

pub const MAP_FIXED: c_int = 0x10;

pub const SIGBUS: c_int = 7;
pub const SIGINT: c_int = 2;
pub const SIGTERM: c_int = 15;
/// Restart interruptible syscalls instead of surfacing EINTR.
pub const SA_RESTART: c_int = 0x10000000;
/// Deliver the three-argument `sa_sigaction` handler form (the second
/// argument carries `siginfo_t`, including the faulting address).
pub const SA_SIGINFO: c_int = 4;

/// `siginfo_t` as the kernel lays it out on 64-bit Linux (x86_64 and
/// aarch64): three ints, implicit padding to an 8-byte boundary, then a
/// 112-byte union whose first field for the memory-fault signals
/// (SIGBUS/SIGSEGV) is the faulting address.  128 bytes total.  Only
/// ever read through a pointer handed to a signal handler — never
/// constructed from Rust.
#[repr(C)]
pub struct siginfo_t {
    pub si_signo: c_int,
    pub si_errno: c_int,
    pub si_code: c_int,
    _pad0: c_int,
    pub si_addr: usize,
    _pad: [usize; 13],
}

/// `struct sigaction` as glibc and musl lay it out on 64-bit Linux
/// (x86_64 and aarch64): handler pointer, a 1024-bit signal mask, the
/// flags (padded to 8), and the restorer slot — 152 bytes total.  The
/// libc wrapper manages the actual `SA_RESTORER` trampoline itself, so
/// `sa_restorer` stays zero here.  Handlers are stored as `usize` so
/// `SIG_DFL`/`SIG_IGN` (0/1) and real `extern "C" fn(c_int)` pointers
/// share the field.
#[repr(C)]
pub struct sigaction {
    pub sa_handler: usize,
    pub sa_mask: [u64; 16],
    pub sa_flags: c_int,
    pub sa_restorer: usize,
}

extern "C" {
    pub fn mmap(
        addr: *mut c_void,
        length: size_t,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: off_t,
    ) -> *mut c_void;

    pub fn munmap(addr: *mut c_void, length: size_t) -> c_int;

    pub fn mincore(addr: *mut c_void, length: size_t, vec: *mut c_uchar) -> c_int;

    pub fn sigaction(signum: c_int, act: *const sigaction, oldact: *mut sigaction) -> c_int;

    /// Deliver `sig` to the calling thread (tests exercise the handler
    /// path without a second process).
    pub fn raise(sig: c_int) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anonymous_map_roundtrip() {
        // SAFETY: a plain private anonymous mapping, unmapped at the end.
        unsafe {
            let p = mmap(
                std::ptr::null_mut(),
                4096,
                PROT_READ | PROT_WRITE,
                MAP_PRIVATE | MAP_ANONYMOUS,
                -1,
                0,
            );
            assert_ne!(p, MAP_FAILED);
            let bytes = p as *mut u8;
            *bytes = 7;
            assert_eq!(*bytes, 7);
            let mut resident = [0u8; 1];
            assert_eq!(mincore(p, 4096, resident.as_mut_ptr()), 0);
            assert_eq!(munmap(p, 4096), 0);
        }
    }
}
