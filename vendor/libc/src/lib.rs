//! Offline drop-in subset of the `libc` crate: exactly the FFI surface
//! `util::mmap` (anonymous/file mappings plus `mincore` residency
//! queries), `util::signal` (`sigaction` for SIGTERM-driven graceful
//! drain), and `util::poll` (`poll(2)` readiness multiplexing, the
//! self-pipe wakeup, and `RLIMIT_NOFILE` for high-connection load
//! tests) need on 64-bit Linux.  Declaring the prototypes locally links
//! against the system libc that std already pulls in; no crates.io
//! access is required.

#![allow(non_camel_case_types)]

pub use std::ffi::c_void;

pub type c_int = i32;
pub type c_char = i8;
pub type c_uchar = u8;
pub type c_short = i16;
pub type c_ulong = u64;
pub type size_t = usize;
pub type ssize_t = isize;
pub type off_t = i64;
/// `nfds_t` — the `poll(2)` fd-count type (unsigned long on Linux).
pub type nfds_t = c_ulong;

pub const PROT_READ: c_int = 0x1;
pub const PROT_WRITE: c_int = 0x2;

pub const MAP_SHARED: c_int = 0x01;
pub const MAP_PRIVATE: c_int = 0x02;
pub const MAP_ANONYMOUS: c_int = 0x20;
pub const MAP_NORESERVE: c_int = 0x4000;

pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

pub const MAP_FIXED: c_int = 0x10;

// `poll(2)` event bits (asm-generic values, shared by x86_64/aarch64).
pub const POLLIN: c_short = 0x1;
pub const POLLOUT: c_short = 0x4;
pub const POLLERR: c_short = 0x8;
pub const POLLHUP: c_short = 0x10;
pub const POLLNVAL: c_short = 0x20;

// `pipe2(2)` flags (octal in the kernel headers).
pub const O_NONBLOCK: c_int = 0o4000;
pub const O_CLOEXEC: c_int = 0o2000000;

/// Per-process open-file-descriptor cap (`getrlimit`/`setrlimit`).
pub const RLIMIT_NOFILE: c_int = 7;

/// One `poll(2)` registration: fd, requested events, returned events.
#[repr(C)]
#[derive(Clone, Copy)]
pub struct pollfd {
    pub fd: c_int,
    pub events: c_short,
    pub revents: c_short,
}

/// `struct rlimit` on 64-bit Linux: soft and hard caps as u64.
#[repr(C)]
#[derive(Clone, Copy)]
pub struct rlimit {
    pub rlim_cur: u64,
    pub rlim_max: u64,
}

pub const SIGBUS: c_int = 7;
pub const SIGINT: c_int = 2;
pub const SIGTERM: c_int = 15;
/// Restart interruptible syscalls instead of surfacing EINTR.
pub const SA_RESTART: c_int = 0x10000000;
/// Deliver the three-argument `sa_sigaction` handler form (the second
/// argument carries `siginfo_t`, including the faulting address).
pub const SA_SIGINFO: c_int = 4;

/// `siginfo_t` as the kernel lays it out on 64-bit Linux (x86_64 and
/// aarch64): three ints, implicit padding to an 8-byte boundary, then a
/// 112-byte union whose first field for the memory-fault signals
/// (SIGBUS/SIGSEGV) is the faulting address.  128 bytes total.  Only
/// ever read through a pointer handed to a signal handler — never
/// constructed from Rust.
#[repr(C)]
pub struct siginfo_t {
    pub si_signo: c_int,
    pub si_errno: c_int,
    pub si_code: c_int,
    _pad0: c_int,
    pub si_addr: usize,
    _pad: [usize; 13],
}

/// `struct sigaction` as glibc and musl lay it out on 64-bit Linux
/// (x86_64 and aarch64): handler pointer, a 1024-bit signal mask, the
/// flags (padded to 8), and the restorer slot — 152 bytes total.  The
/// libc wrapper manages the actual `SA_RESTORER` trampoline itself, so
/// `sa_restorer` stays zero here.  Handlers are stored as `usize` so
/// `SIG_DFL`/`SIG_IGN` (0/1) and real `extern "C" fn(c_int)` pointers
/// share the field.
#[repr(C)]
pub struct sigaction {
    pub sa_handler: usize,
    pub sa_mask: [u64; 16],
    pub sa_flags: c_int,
    pub sa_restorer: usize,
}

extern "C" {
    pub fn mmap(
        addr: *mut c_void,
        length: size_t,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: off_t,
    ) -> *mut c_void;

    pub fn munmap(addr: *mut c_void, length: size_t) -> c_int;

    pub fn mincore(addr: *mut c_void, length: size_t, vec: *mut c_uchar) -> c_int;

    pub fn sigaction(signum: c_int, act: *const sigaction, oldact: *mut sigaction) -> c_int;

    /// Deliver `sig` to the calling thread (tests exercise the handler
    /// path without a second process).
    pub fn raise(sig: c_int) -> c_int;

    pub fn poll(fds: *mut pollfd, nfds: nfds_t, timeout: c_int) -> c_int;

    pub fn pipe2(pipefd: *mut c_int, flags: c_int) -> c_int;

    pub fn read(fd: c_int, buf: *mut c_void, count: size_t) -> ssize_t;

    pub fn write(fd: c_int, buf: *const c_void, count: size_t) -> ssize_t;

    pub fn close(fd: c_int) -> c_int;

    pub fn getrlimit(resource: c_int, rlim: *mut rlimit) -> c_int;

    pub fn setrlimit(resource: c_int, rlim: *const rlimit) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anonymous_map_roundtrip() {
        // SAFETY: a plain private anonymous mapping, unmapped at the end.
        unsafe {
            let p = mmap(
                std::ptr::null_mut(),
                4096,
                PROT_READ | PROT_WRITE,
                MAP_PRIVATE | MAP_ANONYMOUS,
                -1,
                0,
            );
            assert_ne!(p, MAP_FAILED);
            let bytes = p as *mut u8;
            *bytes = 7;
            assert_eq!(*bytes, 7);
            let mut resident = [0u8; 1];
            assert_eq!(mincore(p, 4096, resident.as_mut_ptr()), 0);
            assert_eq!(munmap(p, 4096), 0);
        }
    }

    #[test]
    fn pipe2_poll_roundtrip() {
        // SAFETY: a private nonblocking pipe, written and polled within
        // the test, both ends closed at the end.
        unsafe {
            let mut fds = [0 as c_int; 2];
            assert_eq!(pipe2(fds.as_mut_ptr(), O_NONBLOCK | O_CLOEXEC), 0);
            let (rd, wr) = (fds[0], fds[1]);

            // nothing readable yet: poll with a zero timeout returns 0
            let mut pfd = pollfd { fd: rd, events: POLLIN, revents: 0 };
            assert_eq!(poll(&mut pfd, 1, 0), 0);

            let byte = [1u8];
            assert_eq!(write(wr, byte.as_ptr() as *const c_void, 1), 1);
            let mut pfd = pollfd { fd: rd, events: POLLIN, revents: 0 };
            assert_eq!(poll(&mut pfd, 1, 1000), 1);
            assert_ne!(pfd.revents & POLLIN, 0);

            let mut buf = [0u8; 8];
            assert_eq!(read(rd, buf.as_mut_ptr() as *mut c_void, 8), 1);
            assert_eq!(buf[0], 1);

            assert_eq!(close(rd), 0);
            assert_eq!(close(wr), 0);
        }
    }

    #[test]
    fn rlimit_nofile_is_readable() {
        let mut lim = rlimit { rlim_cur: 0, rlim_max: 0 };
        // SAFETY: plain out-parameter read of the process fd limit.
        let rc = unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) };
        assert_eq!(rc, 0);
        assert!(lim.rlim_cur >= 1, "a process always has some fd budget");
        assert!(lim.rlim_max >= lim.rlim_cur);
    }
}
