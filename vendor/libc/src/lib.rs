//! Offline drop-in subset of the `libc` crate: exactly the FFI surface
//! `util::mmap` needs (anonymous/file mappings plus `mincore` residency
//! queries) on 64-bit Linux.  Declaring the prototypes locally links
//! against the system libc that std already pulls in; no crates.io
//! access is required.

#![allow(non_camel_case_types)]

pub use std::ffi::c_void;

pub type c_int = i32;
pub type c_char = i8;
pub type c_uchar = u8;
pub type size_t = usize;
pub type off_t = i64;

pub const PROT_READ: c_int = 0x1;
pub const PROT_WRITE: c_int = 0x2;

pub const MAP_SHARED: c_int = 0x01;
pub const MAP_PRIVATE: c_int = 0x02;
pub const MAP_ANONYMOUS: c_int = 0x20;
pub const MAP_NORESERVE: c_int = 0x4000;

pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

extern "C" {
    pub fn mmap(
        addr: *mut c_void,
        length: size_t,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: off_t,
    ) -> *mut c_void;

    pub fn munmap(addr: *mut c_void, length: size_t) -> c_int;

    pub fn mincore(addr: *mut c_void, length: size_t, vec: *mut c_uchar) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anonymous_map_roundtrip() {
        // SAFETY: a plain private anonymous mapping, unmapped at the end.
        unsafe {
            let p = mmap(
                std::ptr::null_mut(),
                4096,
                PROT_READ | PROT_WRITE,
                MAP_PRIVATE | MAP_ANONYMOUS,
                -1,
                0,
            );
            assert_ne!(p, MAP_FAILED);
            let bytes = p as *mut u8;
            *bytes = 7;
            assert_eq!(*bytes, 7);
            let mut resident = [0u8; 1];
            assert_eq!(mincore(p, 4096, resident.as_mut_ptr()), 0);
            assert_eq!(munmap(p, 4096), 0);
        }
    }
}
