//! Offline stub of the `xla` crate (PJRT bindings over xla_extension).
//!
//! The real bindings need the xla_extension C++ library, which the
//! offline build environment does not ship.  This stub keeps the crate
//! API-compatible so the whole workspace builds and the pure-rust paths
//! (lattice math, memstore, batch engine, serving plumbing) run:
//!
//! * [`Literal`] is fully functional on the host (f32/i32 arrays,
//!   reshape, tuple access) — `ArtifactState` construction, checkpoint
//!   marshalling and their tests work unchanged;
//! * [`PjRtClient::cpu`] returns an error, so every artifact-executing
//!   path fails fast with a clear message and the integration tests /
//!   benches skip exactly as they do when artifacts are missing.
//!
//! Swap this path dependency for the real `xla` crate to restore AOT
//! artifact execution; no source changes are needed elsewhere.

use std::fmt;
use std::path::Path;

/// Stub error type (mirrors `xla::Error` closely enough for `?`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: this build vendors the offline xla stub (vendor/xla); \
         artifact execution requires the real xla_extension bindings"
            .to_string(),
    )
}

/// Element types we can carry in a host literal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host storage behind a [`Literal`].
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Native element types that map onto [`ElementType`] tags.
pub trait ArrayElement: Copy + Sized + 'static {
    const TY: ElementType;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Payload;
    #[doc(hidden)]
    fn unwrap_ref(p: &Payload) -> Option<&[Self]>;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;

    fn wrap(v: Vec<Self>) -> Payload {
        Payload::F32(v)
    }

    fn unwrap_ref(p: &Payload) -> Option<&[Self]> {
        match p {
            Payload::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;

    fn wrap(v: Vec<Self>) -> Payload {
        Payload::I32(v)
    }

    fn unwrap_ref(p: &Payload) -> Option<&[Self]> {
        match p {
            Payload::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Shape of a non-tuple literal: element type + dimensions.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side tensor value (fully functional in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a native slice.
    pub fn vec1<T: ArrayElement>(data: &[T]) -> Literal {
        Literal { payload: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Same data, new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: {} elements into shape {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(v) => v.iter().map(Literal::element_count).sum(),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::I32(_) => ElementType::S32,
            Payload::Tuple(_) => return Err(Error("array_shape of a tuple literal".into())),
        };
        Ok(ArrayShape { ty, dims: self.dims.clone() })
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        T::unwrap_ref(&self.payload)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(v) => Ok(v),
            _ => Err(Error("to_tuple of a non-tuple literal".into())),
        }
    }
}

/// Stub PJRT client: construction reports the backend as unavailable.
#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub: parsing requires the real bindings).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle (unreachable in the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer handle (unreachable in the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_readback() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert!(matches!(shape.ty(), ElementType::F32));
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("unavailable"));
    }
}
