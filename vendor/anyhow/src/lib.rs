//! Offline drop-in subset of the `anyhow` error crate.
//!
//! The build environment has no crates.io access, so this workspace
//! vendors the small slice of `anyhow` the codebase actually uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros, and the [`Context`] extension trait for `Result` and
//! `Option`.  Errors are flattened to a context chain of strings —
//! `{e}` prints the outermost message, `{e:#}` the full chain joined
//! with `": "`, and `{e:?}` an `anyhow`-style "Caused by:" listing.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `From<E: std::error::Error>` conversion (used by `?`) coherent.

use std::fmt;

/// A flattened error: a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (innermost stays last).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Anything convertible into an [`Error`] (the coherence shim that lets
/// [`Context`] apply to both `Result<T, impl std::error::Error>` and
/// `Result<T, anyhow::Error>`).
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn display_and_alternate() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: boom 42");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<u64> {
            Ok("12x".parse::<u64>()?)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn context_on_anyhow_and_std_results() {
        let r: Result<(), std::num::ParseIntError> = "x".parse::<i32>().map(|_| ());
        assert_eq!(format!("{:#}", r.context("ctx").unwrap_err()).split(": ").next(), Some("ctx"));
        let r2: Result<()> = fails();
        assert!(r2.with_context(|| "later").is_err());
        let o: Option<u8> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
    }
}
