//! Offline drop-in subset of the `log` facade crate.
//!
//! Provides the `error!`/`warn!`/`info!`/`debug!`/`trace!` macros, the
//! [`Log`] trait, [`set_logger`] / [`set_max_level`], and the
//! [`Record`]/[`Metadata`] types — enough for `util::logger`'s stderr
//! backend.  Like the real facade, everything is disabled until a
//! logger and max level are installed.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of one log record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // f.pad so width/alignment specs like "{:5}" work
        f.pad(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

/// Global verbosity filter (`Off` disables everything).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Level + target of a record, available before formatting happens.
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the preformatted arguments.
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }

    fn log(&self, _: &Record) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn logger() -> &'static dyn Log {
    LOGGER.get().copied().unwrap_or(&NOP)
}

#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize <= MAX_LEVEL.load(Ordering::Relaxed) {
        let record = Record { metadata: Metadata { level, target }, args };
        let l = logger();
        if l.enabled(record.metadata()) {
            l.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Error, ::std::module_path!(), ::std::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Warn, ::std::module_path!(), ::std::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Info, ::std::module_path!(), ::std::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Debug, ::std::module_path!(), ::std::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__log($crate::Level::Trace, ::std::module_path!(), ::std::format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }

        fn log(&self, record: &Record) {
            assert!(!record.target().is_empty());
            HITS.fetch_add(1, Ordering::Relaxed);
        }

        fn flush(&self) {}
    }

    #[test]
    fn filtering_and_dispatch() {
        static C: Counter = Counter;
        set_logger(&C).unwrap();
        set_max_level(LevelFilter::Info);
        info!("counted {}", 1);
        debug!("not counted");
        assert_eq!(HITS.load(Ordering::Relaxed), 1);
        assert!(set_logger(&C).is_err());
    }
}
